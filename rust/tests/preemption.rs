//! DESIGN.md §15 acceptance suite: route-aware optimistic admission
//! with preemption and recompute resume — graceful degradation when
//! the KV pool runs dry.
//!
//! The invariants pinned here, per ISSUE 10's acceptance gates:
//! * with a pool sized BELOW the aggregate worst-case demand and
//!   `Optimistic` admission, N concurrent streams ALL complete — no
//!   decode-phase `Overloaded`, no silent close;
//! * preempted streams are bit-identical to uncontended runs, for both
//!   dense and sparse (ring-routed) decode layouts — greedy decode plus
//!   snapshot-verified recompute resume preserves determinism;
//! * `WorstCase` admission on the same undersized pool reproduces
//!   today's serial decisions exactly (zero preemptions);
//! * preemptions / resumes / freed pages are observable in the metrics
//!   summary;
//! * parked victims honor cancel, deadline, and drain like any other
//!   session — a preempted request is never a zombie.
//!
//! Pool geometries are chosen against the synthetic artifact model
//! (4 layers, 4 heads x 8 dims, sa_buf 128, prefill buckets
//! [128, 256, 512, 1024]) at 32-token pages: a `(prompt 100, max_new
//! 100)` request covers bucket 128 and doubles to 256 mid-decode, so
//! its worst case is 4 * (256/32 + 128/32) = 48 pages, its dense routed
//! footprint 32, and its [Fa, Ssa, Fa, Ssa] sparse-decode footprint 24.
//! The growth at the 128 -> 256 bucket edge is the deterministic
//! starvation point every scenario below leans on.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use flux_attention::config::{AdmissionMode, ServingConfig};
use flux_attention::coordinator::{
    Coordinator, Request, RequestError, Response, SessionEvent, SessionHandle,
};
use flux_attention::engine::EngineHandle;
use flux_attention::router::{AttnMode, DecodeMode, Policy};
use flux_attention::runtime::synthetic;
use flux_attention::util::rng::Rng;
use flux_attention::workload::{generate, Task};

mod common;

const TIMEOUT: Duration = Duration::from_secs(120);
/// Pool page size used by every scenario (tokens per page).
const PAGE_TOKENS: usize = 32;

fn artifacts() -> PathBuf {
    synthetic::ensure_default().expect("artifact generation must not fail")
}

/// Coordinator over a pool of exactly `pages` 32-token pages.
fn start_pooled(pages: usize, cfg: ServingConfig) -> (Arc<Coordinator>, EngineHandle) {
    let engine =
        EngineHandle::spawn_with_pool(artifacts(), PAGE_TOKENS, pages * PAGE_TOKENS).unwrap();
    let coord = Coordinator::start(engine.clone(), cfg).unwrap();
    (coord, engine)
}

fn optimistic(factor: f64) -> ServingConfig {
    ServingConfig {
        admission_mode: AdmissionMode::Optimistic { factor },
        ..Default::default()
    }
}

/// Everything one session's event stream produced (see `chaos.rs`).
#[derive(Debug)]
struct Outcome {
    tokens: Vec<u32>,
    done: Option<Response>,
    error: Option<RequestError>,
    terminals: usize,
    preempted: usize,
    resumed: usize,
}

fn drain(h: &SessionHandle) -> Outcome {
    let mut out = Outcome {
        tokens: vec![],
        done: None,
        error: None,
        terminals: 0,
        preempted: 0,
        resumed: 0,
    };
    while let Some(ev) = h.recv_timeout(TIMEOUT) {
        match ev {
            SessionEvent::Queued => {}
            SessionEvent::Prefilled { first_token, .. } => out.tokens.push(first_token),
            SessionEvent::Token { tok, .. } => out.tokens.push(tok),
            SessionEvent::Preempted { .. } => out.preempted += 1,
            SessionEvent::Resumed { .. } => out.resumed += 1,
            SessionEvent::Done { stats } => {
                out.terminals += 1;
                out.done = Some(stats);
            }
            SessionEvent::Error { error } => {
                out.terminals += 1;
                out.error = Some(error);
            }
        }
    }
    out
}

/// Pump one handle until its first `Preempted` event (the park point).
/// Non-terminal events before it are fine; a terminal is a failure.
fn wait_preempted(h: &SessionHandle) {
    loop {
        match h.recv_timeout(TIMEOUT) {
            Some(SessionEvent::Preempted { .. }) => return,
            Some(SessionEvent::Done { .. }) | Some(SessionEvent::Error { .. }) => {
                panic!("stream terminated before it was ever preempted")
            }
            Some(_) => {}
            None => panic!("stream closed before it was ever preempted"),
        }
    }
}

/// The tentpole gate, dense routes: three `(prompt 100, max_new 100)`
/// Backbone streams against a 56-page pool — below their 144-page
/// aggregate worst case, and too small for two grown streams (2 x 32)
/// plus a third. `WorstCase` admission serves them strictly serially
/// (the reference, zero preemptions); `Optimistic { 0.5 }` co-admits
/// two, the second one's growth at the 128 -> 256 bucket edge starves,
/// the elder is preempted and later resumed — and ALL THREE streams
/// complete bit-identical to the serial reference.
#[test]
fn optimistic_admission_preempts_and_completes_all_dense_streams() {
    let mut rng = Rng::seed_from_u64(91);
    let reqs: Vec<Request> = (0..3)
        .map(|_| Request {
            prompt: generate(Task::PRe, &mut rng, 100).prompt,
            max_new: 100,
            policy: Policy::Backbone,
            ignore_eos: true,
            ..Default::default()
        })
        .collect();

    // reference: the SAME pool under WorstCase admission — today's
    // serial decisions reproduced exactly, no preemption machinery
    let (wc, wc_engine) = start_pooled(56, ServingConfig::default());
    let reference: Vec<Vec<u32>> =
        reqs.iter().map(|r| wc.submit(r.clone()).unwrap().tokens).collect();
    {
        let m = wc.metrics.lock().unwrap();
        assert_eq!(m.preemptions, 0, "WorstCase admission must never preempt");
        assert_eq!(m.requests_completed, 3);
    }
    common::assert_pool_drained(&wc_engine);

    let (coord, engine) = start_pooled(56, optimistic(0.5));
    let handles: Vec<SessionHandle> =
        reqs.iter().map(|r| coord.open(r.clone()).unwrap()).collect();
    let outcomes: Vec<Outcome> = handles.iter().map(drain).collect();

    let mut preempted_streams = 0;
    for (i, o) in outcomes.iter().enumerate() {
        assert_eq!(o.terminals, 1, "stream {i} must see exactly one terminal event");
        assert!(o.error.is_none(), "stream {i} must complete, got {:?}", o.error);
        let done = o.done.as_ref().unwrap();
        assert_eq!(done.tokens.len(), 100, "stream {i} must honor max_new");
        assert_eq!(o.tokens, reference[i], "stream {i}: preempted stream diverged");
        assert_eq!(done.tokens, reference[i], "stream {i}: Done stats diverged");
        assert_eq!(
            o.preempted, o.resumed,
            "stream {i}: every preemption of a completed stream must have resumed"
        );
        if o.preempted > 0 {
            preempted_streams += 1;
        }
    }
    assert!(preempted_streams >= 1, "the undersized pool must have forced a preemption");

    let m = coord.metrics.lock().unwrap();
    assert!(m.preemptions >= 1, "preemptions must be counted: {}", m.summary());
    assert!(m.resumes >= 1, "resumes must be counted: {}", m.summary());
    assert!(m.preempted_pages_freed >= 1, "freed pages must be counted: {}", m.summary());
    assert_eq!(m.preemption_exhausted, 0, "no stream may exhaust its preemption budget");
    assert_eq!(m.requests_completed, 3);
    assert_eq!(m.requests_failed, 0);
    assert_eq!(m.requests_overloaded, 0, "no decode-phase Overloaded under preemption");
    let s = m.summary();
    assert!(s.contains("preemptions="), "{s}");
    assert!(s.contains("resumes="), "{s}");
    assert!(s.contains("preempted_pages_freed="), "{s}");
    drop(m);
    common::assert_pool_drained(&engine);
}

/// Sparse-route variant of the tentpole gate: two `[Fa, Ssa, Fa, Ssa]`
/// sparse-decode streams (routed footprint 24 pages each) on a 44-page
/// pool under `Optimistic { 0.4 }`. Both co-admit; the second stream's
/// FA growth at the bucket edge starves, so the elder — whose sparse
/// rings have WRAPPED by then (131 tokens seen > 128 capacity) — is
/// preempted with ring snapshots and later resumed through the
/// snapshot-verified recompute path. Both streams complete
/// bit-identical to uncontended references.
#[test]
fn preempted_sparse_ring_stream_resumes_bit_identical() {
    let mut rng = Rng::seed_from_u64(92);
    let policy = || Policy::Static {
        modes: vec![AttnMode::Fa, AttnMode::Ssa, AttnMode::Fa, AttnMode::Ssa],
        decode: DecodeMode::Sparse,
    };
    let reqs: Vec<Request> = (0..2)
        .map(|_| Request {
            prompt: generate(Task::PRe, &mut rng, 100).prompt,
            max_new: 100,
            policy: policy(),
            ignore_eos: true,
            ..Default::default()
        })
        .collect();

    // uncontended references on a roomy default pool (pool size never
    // affects the computed stream — only whether it must wait)
    let ref_engine = EngineHandle::spawn(artifacts()).unwrap();
    let ref_coord = Coordinator::start(ref_engine.clone(), ServingConfig::default()).unwrap();
    let reference: Vec<Vec<u32>> =
        reqs.iter().map(|r| ref_coord.submit(r.clone()).unwrap().tokens).collect();
    common::assert_pool_drained(&ref_engine);

    let (coord, engine) = start_pooled(44, optimistic(0.4));
    let handles: Vec<SessionHandle> =
        reqs.iter().map(|r| coord.open(r.clone()).unwrap()).collect();
    let outcomes: Vec<Outcome> = handles.iter().map(drain).collect();

    let mut preempted_streams = 0;
    for (i, o) in outcomes.iter().enumerate() {
        assert_eq!(o.terminals, 1, "stream {i} must see exactly one terminal event");
        assert!(o.error.is_none(), "stream {i} must complete, got {:?}", o.error);
        assert_eq!(
            o.tokens, reference[i],
            "stream {i}: resumed sparse-ring stream diverged from the uncontended run"
        );
        assert_eq!(o.preempted, o.resumed, "stream {i}: unbalanced preempt/resume events");
        if o.preempted > 0 {
            preempted_streams += 1;
        }
    }
    assert!(preempted_streams >= 1, "the undersized pool must have forced a preemption");
    let m = coord.metrics.lock().unwrap();
    assert!(m.preemptions >= 1, "{}", m.summary());
    assert!(m.resumes >= 1, "{}", m.summary());
    assert_eq!(m.requests_completed, 2);
    assert_eq!(m.requests_failed, 0);
    drop(m);
    common::assert_pool_drained(&engine);
}

/// A PARKED victim honors cancellation: once the elder dense stream is
/// preempted (its `Preempted` event is the park point), cancelling it
/// retires it with the typed `Cancelled` — it never resumes, never
/// completes — while the surviving streams run to completion.
#[test]
fn parked_victim_honors_cancel() {
    let mut rng = Rng::seed_from_u64(93);
    let reqs: Vec<Request> = (0..3)
        .map(|_| Request {
            prompt: generate(Task::PRe, &mut rng, 100).prompt,
            max_new: 100,
            policy: Policy::Backbone,
            ignore_eos: true,
            ..Default::default()
        })
        .collect();
    let (coord, engine) = start_pooled(56, optimistic(0.5));
    let handles: Vec<SessionHandle> =
        reqs.iter().map(|r| coord.open(r.clone()).unwrap()).collect();

    // the first-admitted stream is deterministically the first victim:
    // it promotes first, grows first, and is the only non-starved
    // decode-phase candidate when its younger sibling's growth starves
    wait_preempted(&handles[0]);
    handles[0].cancel();
    let o = drain(&handles[0]);
    assert_eq!(o.terminals, 1, "the cancelled victim must see exactly one terminal event");
    assert_eq!(o.error, Some(RequestError::Cancelled));
    assert!(o.done.is_none(), "a cancelled parked victim must never complete");

    // the siblings are untouched
    for (i, h) in handles.iter().enumerate().skip(1) {
        let o = drain(h);
        assert_eq!(o.terminals, 1, "stream {i} must see exactly one terminal event");
        assert!(o.error.is_none(), "stream {i} must complete, got {:?}", o.error);
        assert_eq!(o.done.unwrap().tokens.len(), 100);
    }
    let m = coord.metrics.lock().unwrap();
    assert_eq!(m.requests_cancelled, 1);
    assert_eq!(m.requests_completed, 2);
    assert!(m.preemptions >= 1);
    drop(m);
    common::assert_pool_drained(&engine);
}

/// A PARKED victim honors the drain: once the elder stream is parked,
/// draining lets the in-flight survivor finish its full stream while
/// the victim retires with the typed retryable `Draining` — parked
/// work never outlives the drain deadline as a zombie.
#[test]
fn parked_victim_honors_drain() {
    let mut rng = Rng::seed_from_u64(94);
    let reqs: Vec<Request> = (0..2)
        .map(|_| Request {
            prompt: generate(Task::PRe, &mut rng, 100).prompt,
            max_new: 100,
            policy: Policy::Backbone,
            ignore_eos: true,
            ..Default::default()
        })
        .collect();
    let (coord, engine) = start_pooled(56, optimistic(0.5));
    let ha = coord.open(reqs[0].clone()).unwrap();
    let hb = coord.open(reqs[1].clone()).unwrap();

    wait_preempted(&ha);
    assert!(coord.drain(Duration::from_secs(60)), "drain must complete within the deadline");

    // the parked victim was retired typed and retryable at drain start
    let oa = drain(&ha);
    assert_eq!(oa.terminals, 1, "the parked victim must see exactly one terminal event");
    let err = oa.error.expect("the parked victim must retire with a typed error");
    assert_eq!(err, RequestError::Draining);
    assert!(err.retryable(), "Draining must stay retryable for parked victims");

    // the in-flight survivor finished its whole stream through the drain
    let ob = drain(&hb);
    assert_eq!(ob.terminals, 1);
    assert!(ob.error.is_none(), "drain must never error the in-flight stream: {:?}", ob.error);
    assert_eq!(ob.done.unwrap().tokens.len(), 100);
    assert_eq!(coord.metrics.lock().unwrap().requests_completed, 1);
    drop(engine);
}

/// A PARKED victim honors its deadline: after the elder stream is
/// preempted, a treadmill of follow-on streams keeps at least one
/// promoted stream (routed 32 pages) in flight, so the victim's resume
/// (needing 32 more of the 56-page pool) can never fit while the
/// treadmill spins. Its deadline elapses while it sits parked, and the
/// parked revalidation retires it with the typed `DeadlineExceeded` —
/// never a zombie. The treadmill is throughput-adaptive: a drainer
/// thread retires finished streams while the test tops the pipeline
/// back up, so the pool stays contended past the deadline on fast and
/// slow machines alike.
#[test]
fn parked_victim_honors_deadline() {
    const DEADLINE_MS: u64 = 1500;
    const TREADMILL_MS: u64 = 2400;

    let mut rng = Rng::seed_from_u64(95);
    let (coord, engine) = start_pooled(
        56,
        ServingConfig {
            admission_mode: AdmissionMode::Optimistic { factor: 0.5 },
            // treadmill streams may collide at their own bucket edges;
            // give them headroom so none exhausts its retry budget
            max_preemptions: 8,
            ..Default::default()
        },
    );
    let fresh = |rng: &mut Rng, deadline_ms: Option<u64>| Request {
        prompt: generate(Task::PRe, rng, 100).prompt,
        max_new: 100,
        policy: Policy::Backbone,
        deadline_ms,
        ignore_eos: true,
        ..Default::default()
    };

    // the victim carries the deadline; its sibling forces the preemption
    let t0 = std::time::Instant::now();
    let ha = coord.open(fresh(&mut rng, Some(DEADLINE_MS))).unwrap();
    let hb = coord.open(fresh(&mut rng, None)).unwrap();
    wait_preempted(&ha);

    // drainer: retires treadmill streams in FIFO order so the opener
    // knows how many are still outstanding without consuming ha
    let outstanding = Arc::new(std::sync::atomic::AtomicUsize::new(0));
    let (mill_tx, mill_rx) = std::sync::mpsc::channel::<SessionHandle>();
    let drainer = {
        let outstanding = Arc::clone(&outstanding);
        std::thread::spawn(move || {
            let mut failures = vec![];
            let mut completed = 0usize;
            while let Ok(h) = mill_rx.recv() {
                let o = drain(&h);
                if let Some(e) = o.error {
                    failures.push(e);
                } else {
                    completed += 1;
                }
                outstanding.fetch_sub(1, std::sync::atomic::Ordering::SeqCst);
            }
            (completed, failures)
        })
    };
    outstanding.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
    mill_tx.send(hb).unwrap();

    // top the treadmill up to five outstanding streams until the
    // victim's deadline has passed with margin; with FIFO admission at
    // most two run concurrently, so the pool never goes idle in between
    while t0.elapsed() < Duration::from_millis(TREADMILL_MS) {
        if outstanding.load(std::sync::atomic::Ordering::SeqCst) < 5 {
            match coord.open(fresh(&mut rng, None)) {
                Ok(h) => {
                    outstanding.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
                    mill_tx.send(h).unwrap();
                }
                // a full queue just means the treadmill is already deep
                Err(e) => {
                    assert!(e.retryable(), "treadmill admission failed non-retryably: {e:?}")
                }
            }
        }
        std::thread::sleep(Duration::from_millis(5));
    }

    let oa = drain(&ha);
    assert_eq!(oa.terminals, 1, "the expired victim must see exactly one terminal event");
    assert_eq!(
        oa.error,
        Some(RequestError::DeadlineExceeded),
        "a parked victim must honor its deadline"
    );
    assert!(oa.done.is_none(), "an expired parked victim must never complete");

    // the treadmill streams all ran to completion
    drop(mill_tx);
    let (completed, failures) = drainer.join().unwrap();
    assert!(failures.is_empty(), "treadmill streams failed: {failures:?}");
    assert!(completed >= 1);

    let m = coord.metrics.lock().unwrap();
    assert_eq!(m.requests_expired, 1, "{}", m.summary());
    assert!(m.preemptions >= 1, "{}", m.summary());
    drop(m);
    common::assert_pool_drained(&engine);
}
