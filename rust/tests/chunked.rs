//! Chunked-prefill acceptance tests (DESIGN.md §10): splitting a
//! prompt into history-aware chunks must be bit-identical to the
//! monolithic prefill — first token, routed cache layout and every
//! subsequent decode step — across chunk sizes, all four attention
//! modes, the 128 -> 256 bucket growth edge and the sparse-ring wrap;
//! and a mid-prefill cancel must free the engine slot and the partially
//! staged KV.
//!
//! Artifacts resolution mirrors `integration.rs`: hermetic synthetic
//! artifacts — every test executes on every `cargo test`.

use std::path::PathBuf;
use std::time::Duration;

use flux_attention::config::ServingConfig;
use flux_attention::coordinator::{Coordinator, Request, RequestError, SessionEvent};
use flux_attention::engine::{ChunkOutcome, Engine, EngineHandle, PrefillReport};
use flux_attention::router::{AttnMode, DecodeMode, Policy};
use flux_attention::runtime::synthetic;

mod common;

const TIMEOUT: Duration = Duration::from_secs(120);

fn artifacts() -> PathBuf {
    synthetic::ensure_default().expect("artifact generation must not fail")
}

fn prompt_of(len: usize) -> Vec<u32> {
    (0..len).map(|i| ((i * 7 + 3) % 500) as u32).collect()
}

/// Drive a chunked prefill to completion, returning the request id and
/// the report.
fn run_chunked(
    engine: &mut Engine,
    prompt: &[u32],
    policy: &Policy,
    chunk: usize,
) -> (u64, PrefillReport) {
    let job = engine.prefill_open(prompt, policy, "balanced", chunk).unwrap();
    loop {
        match engine.prefill_chunk(job).unwrap() {
            ChunkOutcome::More { consumed, total_tokens } => {
                assert!(consumed < total_tokens, "More must mean unfinished");
            }
            ChunkOutcome::Done { id, report } => return (id, report),
        }
    }
}

/// The tentpole determinism property: for every attention mode and
/// chunk size in {32, 64, whole-prompt}, chunked prefill must produce
/// the same first token, routing, KV footprint and decode stream as the
/// monolithic prefill. The 200-token prompt buckets at 256 (the
/// 128 -> 256 growth edge sits inside the chunk sequence) and, under
/// sparse decode, wraps the sink 16 + local 64 ring during prefill —
/// the edges where chunked staging would diverge first.
#[test]
fn chunked_prefill_bit_identical_to_monolithic() {
    let dir = artifacts();
    let mut mono = Engine::load(&dir).unwrap();
    let mut chunked = Engine::load(&dir).unwrap();
    let n_layers = mono.cfg().model.n_layers;
    let prompt = prompt_of(200);
    let steps = 20;

    let mut cases: Vec<(Policy, &'static str)> = vec![
        (Policy::Static { modes: vec![AttnMode::Fa; n_layers], decode: DecodeMode::Sparse }, "fa"),
        (Policy::Static { modes: vec![AttnMode::Ssa; n_layers], decode: DecodeMode::Sparse }, "ssa"),
        (Policy::Static { modes: vec![AttnMode::Ta; n_layers], decode: DecodeMode::Sparse }, "ta"),
        (Policy::Static { modes: vec![AttnMode::Xa; n_layers], decode: DecodeMode::Sparse }, "xa"),
        // dense decode keeps full caches even for SA-routed layers
        (
            Policy::Static { modes: vec![AttnMode::Ssa; n_layers], decode: DecodeMode::Dense },
            "ssa-dense",
        ),
    ];
    // per-layer mixed routing: both cache layouts in one request
    let mixed: Vec<AttnMode> = (0..n_layers)
        .map(|l| if l % 2 == 0 { AttnMode::Fa } else { AttnMode::Ssa })
        .collect();
    cases.push((Policy::Static { modes: mixed, decode: DecodeMode::Sparse }, "mixed"));

    for (policy, label) in &cases {
        for &chunk in &[32usize, 64, 0] {
            // fresh requests per configuration: greedy decode is
            // per-request deterministic, so the streams are comparable
            let (mid, mrep) = mono.prefill(&prompt, policy, "balanced").unwrap();
            let (cid, crep) = run_chunked(&mut chunked, &prompt, policy, chunk);
            assert_eq!(chunked.active_prefill_jobs(), 0, "{label}: job must retire on Done");
            assert_eq!(crep.first_token, mrep.first_token, "{label} chunk {chunk}: first token");
            assert_eq!(crep.modes, mrep.modes, "{label} chunk {chunk}: pinned routing");
            assert_eq!(crep.bucket, mrep.bucket, "{label} chunk {chunk}: request bucket");
            assert_eq!(crep.prompt_len, mrep.prompt_len, "{label} chunk {chunk}: prompt len");
            assert_eq!(
                crep.kv_bytes, mrep.kv_bytes,
                "{label} chunk {chunk}: routed cache footprint must match monolithic"
            );
            let expected_chunks = if chunk == 0 { 1 } else { prompt.len().div_ceil(chunk) };
            assert_eq!(crep.chunks, expected_chunks, "{label} chunk {chunk}: chunk count");

            // the decode trajectories must now be indistinguishable
            for step in 0..steps {
                let mt = mono.decode_step(mid).unwrap();
                let ct = chunked.decode_step(cid).unwrap();
                assert_eq!(ct, mt, "{label} chunk {chunk}: decode step {step} diverged");
            }
            mono.release(mid);
            chunked.release(cid);
        }
    }
}

/// Flux-policy chunked prefill routes once on the first chunk (the
/// paper's context-aware routing on the prompt prefix) and pins the
/// decision: the job completes, every layer has a mode, and re-running
/// the same prompt reproduces the same routing and stream.
#[test]
fn chunked_flux_policy_routes_on_first_chunk_and_is_deterministic() {
    let dir = artifacts();
    let mut engine = Engine::load(&dir).unwrap();
    let n_layers = engine.cfg().model.n_layers;
    let prompt = prompt_of(180);
    let policy = Policy::Flux { sa_mode: AttnMode::Ssa, decode: DecodeMode::Sparse };

    let (id1, rep1) = run_chunked(&mut engine, &prompt, &policy, 64);
    assert_eq!(rep1.modes.len(), n_layers);
    assert!((0.0..=1.0).contains(&rep1.omsr));
    let mut stream1 = vec![rep1.first_token];
    for _ in 0..8 {
        stream1.push(engine.decode_step(id1).unwrap());
    }
    engine.release(id1);

    let (id2, rep2) = run_chunked(&mut engine, &prompt, &policy, 64);
    assert_eq!(rep2.modes, rep1.modes, "routing must be deterministic");
    let mut stream2 = vec![rep2.first_token];
    for _ in 0..8 {
        stream2.push(engine.decode_step(id2).unwrap());
    }
    engine.release(id2);
    assert_eq!(stream1, stream2);
}

/// Mid-prefill cancellation at the engine level: dropping a
/// partially-prefilled job must free ALL its staged KV (staging
/// buffers and rings), returning the engine to its pre-job footprint.
#[test]
fn mid_prefill_cancel_frees_partial_kv() {
    let dir = artifacts();
    let mut engine = Engine::load(&dir).unwrap();
    let n_layers = engine.cfg().model.n_layers;
    let policy = Policy::Static {
        modes: (0..n_layers)
            .map(|l| if l % 2 == 0 { AttnMode::Fa } else { AttnMode::Ssa })
            .collect(),
        decode: DecodeMode::Sparse,
    };
    assert_eq!(engine.total_kv_bytes(), 0);

    let prompt = prompt_of(200);
    let job = engine.prefill_open(&prompt, &policy, "balanced", 32).unwrap();
    assert_eq!(engine.active_prefill_jobs(), 1);
    assert!(engine.total_kv_bytes() > 0, "staging allocation must be accounted");
    // run a couple of chunks so real KV is staged mid-prefill
    for _ in 0..2 {
        match engine.prefill_chunk(job).unwrap() {
            ChunkOutcome::More { .. } => {}
            ChunkOutcome::Done { .. } => panic!("200 tokens / 32-chunks cannot finish in 2 calls"),
        }
    }
    assert!(engine.prefill_cancel(job), "cancel must find the job");
    assert_eq!(engine.active_prefill_jobs(), 0);
    assert_eq!(engine.total_kv_bytes(), 0, "partial KV must be freed");
    assert!(!engine.prefill_cancel(job), "double-cancel is a no-op");
    // further chunk calls on the dead job fail cleanly
    assert!(engine.prefill_chunk(job).is_err());

    // the engine still serves fresh work
    let (id, _) = engine.prefill(&prompt, &policy, "balanced").unwrap();
    engine.decode_step(id).unwrap();
    engine.release(id);
}

/// Mid-prefill cancellation at the scheduler level: with one active
/// slot and a long chunked prefill in flight, cancelling the session
/// frees the slot between chunks and the queued request admits and
/// completes. Also pins the new serving metrics: prefill chunks are
/// counted and TTFT lands in the histogram.
#[test]
fn scheduler_mid_prefill_cancel_frees_slot() {
    let engine = EngineHandle::spawn(artifacts()).unwrap();
    let coord = Coordinator::start(
        engine.clone(),
        ServingConfig {
            max_active_requests: 1,
            prefill_chunk_tokens: 32,
            ..Default::default()
        },
    )
    .unwrap();
    // long prompt: 512 tokens = 16 chunks of 32
    let ha = coord
        .open(Request {
            prompt: prompt_of(512),
            max_new: 64,
            ignore_eos: true,
            ..Default::default()
        })
        .unwrap();
    // the queued request waits for A's slot
    let hb = coord
        .open(Request { prompt: prompt_of(100), max_new: 3, ignore_eos: true, ..Default::default() })
        .unwrap();
    ha.cancel();
    let err = loop {
        match ha.recv_timeout(TIMEOUT) {
            Some(SessionEvent::Error { error }) => break error,
            Some(SessionEvent::Done { .. }) => panic!("cancelled session must not complete"),
            Some(_) => {}
            None => panic!("A closed without a terminal event"),
        }
    };
    assert_eq!(err, RequestError::Cancelled);

    // B admits into the freed slot and completes
    let resp = loop {
        match hb.recv_timeout(TIMEOUT) {
            Some(SessionEvent::Done { stats }) => break stats,
            Some(SessionEvent::Error { error }) => panic!("B failed: {error}"),
            Some(_) => {}
            None => panic!("B closed early"),
        }
    };
    assert_eq!(resp.tokens.len(), 3);

    let m = coord.metrics.lock().unwrap();
    assert_eq!(m.requests_cancelled, 1);
    assert_eq!(m.requests_completed, 1);
    assert!(m.prefill_chunks >= 1, "chunk calls must be counted");
    assert!(m.ttft.count() >= 1, "TTFT must land in the histogram");
    drop(m);
    common::assert_pool_drained(&engine);
}

/// A cancelled session queued BEHIND an in-flight long prefill (both
/// holding active slots) is evicted by the prefilling sweep — it gets
/// its terminal event and frees its staged KV without having to reach
/// the front of the chunk queue first, and the front request is
/// unaffected.
#[test]
fn cancel_behind_inflight_prefill_is_swept() {
    let engine = EngineHandle::spawn(artifacts()).unwrap();
    let coord = Coordinator::start(
        engine.clone(),
        ServingConfig {
            max_active_requests: 2,
            prefill_chunk_tokens: 32,
            ..Default::default()
        },
    )
    .unwrap();
    let ha = coord
        .open(Request {
            prompt: prompt_of(512),
            max_new: 8,
            ignore_eos: true,
            ..Default::default()
        })
        .unwrap();
    let hb = coord
        .open(Request {
            prompt: prompt_of(512),
            max_new: 8,
            ignore_eos: true,
            ..Default::default()
        })
        .unwrap();
    // B sits behind A's 16-chunk prefill; cancel it there
    hb.cancel();
    let err = loop {
        match hb.recv_timeout(TIMEOUT) {
            Some(SessionEvent::Error { error }) => break error,
            Some(SessionEvent::Done { .. }) => panic!("cancelled session must not complete"),
            Some(_) => {}
            None => panic!("B closed without a terminal event"),
        }
    };
    assert_eq!(err, RequestError::Cancelled);
    // the front request is unaffected and completes
    let resp = loop {
        match ha.recv_timeout(TIMEOUT) {
            Some(SessionEvent::Done { stats }) => break stats,
            Some(SessionEvent::Error { error }) => panic!("A failed: {error}"),
            Some(_) => {}
            None => panic!("A closed early"),
        }
    };
    assert_eq!(resp.tokens.len(), 8);
    let m = coord.metrics.lock().unwrap();
    assert_eq!(m.requests_cancelled, 1);
    assert_eq!(m.requests_completed, 1);
    drop(m);
    common::assert_pool_drained(&engine);
}

/// Long prompts prefill incrementally while short streams keep
/// decoding: with a chunked scheduler, a short request admitted AFTER a
/// long one starts streaming tokens BEFORE the long prefill finishes
/// would be timing-dependent — so instead we pin the structural
/// guarantee: both complete, the long request's prefill took multiple
/// chunks, and its stream equals the monolithic scheduler's stream.
#[test]
fn chunked_scheduler_streams_match_monolithic_scheduler() {
    let long = prompt_of(512);
    let short = prompt_of(90);
    let run = |chunk_tokens: usize| -> (Vec<u32>, Vec<u32>, u64) {
        let engine = EngineHandle::spawn(artifacts()).unwrap();
        let coord = Coordinator::start(
            engine.clone(),
            ServingConfig { prefill_chunk_tokens: chunk_tokens, ..Default::default() },
        )
        .unwrap();
        let hl = coord
            .open(Request {
                prompt: long.clone(),
                max_new: 6,
                ignore_eos: true,
                ..Default::default()
            })
            .unwrap();
        let hs = coord
            .open(Request {
                prompt: short.clone(),
                max_new: 6,
                ignore_eos: true,
                ..Default::default()
            })
            .unwrap();
        let drain = |h: flux_attention::coordinator::SessionHandle| -> Vec<u32> {
            let mut toks = vec![];
            loop {
                match h.recv_timeout(TIMEOUT) {
                    Some(SessionEvent::Prefilled { first_token, .. }) => toks.push(first_token),
                    Some(SessionEvent::Token { tok, .. }) => toks.push(tok),
                    Some(SessionEvent::Done { .. }) => return toks,
                    Some(SessionEvent::Error { error }) => panic!("stream failed: {error}"),
                    Some(_) => {}
                    None => panic!("stream closed early"),
                }
            }
        };
        let long_toks = drain(hl);
        let short_toks = drain(hs);
        let chunks = coord.metrics.lock().unwrap().prefill_chunks;
        common::assert_pool_drained(&engine);
        (long_toks, short_toks, chunks)
    };
    let (mono_long, mono_short, mono_chunks) = run(0);
    let (ch_long, ch_short, ch_chunks) = run(128);
    assert_eq!(ch_long, mono_long, "long stream must be scheduler-independent");
    assert_eq!(ch_short, mono_short, "short stream must be scheduler-independent");
    // monolithic: one chunk per request; chunked: 512/128 = 4 for the
    // long prompt + 1 for the short one
    assert_eq!(mono_chunks, 2);
    assert_eq!(ch_chunks, 5);
}
