//! Shared integration-test helpers.

#![allow(dead_code)]

use std::time::{Duration, Instant};

use flux_attention::engine::EngineHandle;

/// Assert the engine's KV pool has fully drained: every page free and
/// the free list coalesced back to one contiguous run (DESIGN.md §12).
///
/// Polls instead of checking once: retirement releases pages from the
/// scheduler thread between engine rounds (cancel-on-drop in
/// particular lands on the *next* sweep), so a just-finished test can
/// legitimately observe a page still in flight for a few rounds.
pub fn assert_pool_drained(engine: &EngineHandle) {
    let deadline = Instant::now() + Duration::from_secs(10);
    let mut last_err;
    loop {
        match engine.pool_drained() {
            Ok(()) => return,
            Err(e) => last_err = e.to_string(),
        }
        if Instant::now() >= deadline {
            panic!("kv pool failed to drain within 10s: {last_err}");
        }
        std::thread::sleep(Duration::from_millis(20));
    }
}
