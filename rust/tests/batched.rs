//! Batched-decode acceptance tests (DESIGN.md §9): `Engine::decode_batch`
//! must be bit-identical in token order to N independent serial
//! `decode_step` loops across per-request-divergent routing, the
//! sparse-ring wrap and the 128 -> 256 FA bucket growth edge mid-batch;
//! the batch reply must piggyback KV-transfer totals and per-mode group
//! occupancy; and the scheduler must run one batched round per token
//! with mid-round cancellation shrinking the next batch.
//!
//! Artifacts resolution mirrors `integration.rs`: hermetic synthetic
//! artifacts — every test executes on every `cargo test`.

use std::path::PathBuf;
use std::time::Duration;

use flux_attention::config::ServingConfig;
use flux_attention::coordinator::{Coordinator, Request, RequestError, SessionEvent};
use flux_attention::engine::{Engine, EngineHandle};
use flux_attention::prop_assert_eq;
use flux_attention::router::{AttnMode, DecodeMode, Policy};
use flux_attention::runtime::synthetic;
use flux_attention::util::prop::check;
use flux_attention::util::rng::Rng;
use flux_attention::workload::{generate, Task};

mod common;

const TIMEOUT: Duration = Duration::from_secs(120);

fn artifacts() -> PathBuf {
    synthetic::ensure_default().expect("artifact generation must not fail")
}

/// The tentpole determinism property: for random mixed-mode active sets
/// (each request with its own per-layer FA/SA routing and prompt
/// length), the batched decode path must produce token streams
/// bit-identical to N independent serial `decode_step` loops. Prompt
/// lengths straddle the 128 prefill bucket and 40 decode rounds push
/// shorter requests across the 128 -> 256 FA bucket growth edge
/// mid-batch while sparse rings (sink 16 + local 64) wrap — the edges
/// where the two paths would diverge first.
#[test]
fn batched_decode_bit_identical_to_serial_property() {
    let dir = artifacts();
    let mut e_serial = Engine::load(&dir).unwrap();
    e_serial.set_batch_decode(false); // the FLUX_BATCH_DECODE=0 path
    let mut e_batch = Engine::load(&dir).unwrap();
    assert!(e_batch.batch_decode(), "batched decode must default on");
    let n_layers = e_serial.cfg().model.n_layers;
    let tasks = [Task::PRe, Task::Gov, Task::Qasper, Task::Trec];
    check("batched_decode_vs_serial", 4, |rng| {
        let b = 2 + rng.gen_range(3); // 2..=4 requests
        let mut prompts = Vec::with_capacity(b);
        let mut policies = Vec::with_capacity(b);
        for _ in 0..b {
            let len = rng.range(100, 160);
            let task = tasks[rng.gen_range(tasks.len())];
            prompts.push(generate(task, rng, len).prompt);
            // per-request-divergent per-layer routing, sparse decode:
            // some layers full caches, some sparse rings, differently
            // per batchmate
            let modes: Vec<AttnMode> = (0..n_layers)
                .map(|_| if rng.f64() < 0.5 { AttnMode::Fa } else { AttnMode::Ssa })
                .collect();
            policies.push(Policy::Static { modes, decode: DecodeMode::Sparse });
        }
        let steps = 40;

        // serial reference: N independent decode loops
        let mut serial_tokens: Vec<Vec<u32>> = Vec::with_capacity(b);
        for (prompt, policy) in prompts.iter().zip(&policies) {
            let (id, report) =
                e_serial.prefill(prompt, policy, "balanced").map_err(|e| e.to_string())?;
            let mut toks = vec![report.first_token];
            for _ in 0..steps {
                toks.push(e_serial.decode_step(id).map_err(|e| e.to_string())?);
            }
            e_serial.release(id);
            serial_tokens.push(toks);
        }

        // batched: same prefills, then one decode_batch round per token
        let mut ids = Vec::with_capacity(b);
        let mut batch_tokens: Vec<Vec<u32>> = Vec::with_capacity(b);
        for (prompt, policy) in prompts.iter().zip(&policies) {
            let (id, report) =
                e_batch.prefill(prompt, policy, "balanced").map_err(|e| e.to_string())?;
            ids.push(id);
            batch_tokens.push(vec![report.first_token]);
        }
        for _ in 0..steps {
            for (toks, res) in batch_tokens.iter_mut().zip(e_batch.decode_batch(&ids)) {
                toks.push(res.map_err(|e| e.to_string())?);
            }
        }
        for &id in &ids {
            e_batch.release(id);
        }
        prop_assert_eq!(&serial_tokens, &batch_tokens);
        Ok(())
    });
}

/// The batch reply carries everything the scheduler needs for the
/// round: per-request tokens, KV totals (no separate poll) and the
/// per-mode (layer, mode) group occupancy; an unknown id fails its own
/// slot without poisoning batchmates.
#[test]
fn decode_batch_reply_carries_totals_and_group_occupancy() {
    let dir = artifacts();
    let mut engine = Engine::load(&dir).unwrap();
    let n_layers = engine.cfg().model.n_layers;
    let mut rng = Rng::seed_from_u64(51);
    // balanced router: even layers FA, odd layers SA -> with sparse
    // decode, every request contributes to both groups each round
    let policy = Policy::Flux { sa_mode: AttnMode::Ssa, decode: DecodeMode::Sparse };
    let mut ids = vec![];
    for task in [Task::PRe, Task::Gov] {
        let s = generate(task, &mut rng, 120);
        let (id, _) = engine.prefill(&s.prompt, &policy, "balanced").unwrap();
        ids.push(id);
    }
    ids.push(9999); // unknown request

    let report = engine.decode_batch_report(&ids);
    assert!(report.batched, "RefBackend must take the batched path");
    assert_eq!(report.tokens.len(), 3);
    assert_eq!(report.step_us.len(), 3);
    assert!(report.tokens[0].is_ok() && report.tokens[1].is_ok());
    let err = report.tokens[2].as_ref().unwrap_err().to_string();
    assert!(err.contains("unknown request"), "{err}");
    // 0.5 FA / 0.5 SA routing: both groups occupied every layer
    assert_eq!(report.fa_group_slots, 2 * (n_layers / 2) as u64);
    assert_eq!(report.sa_group_slots, 2 * (n_layers - n_layers / 2) as u64);
    // zero-copy staging: the round borrowed KV, moved none
    assert_eq!(report.kv_transfer.0, 0, "batched fast path must clone zero KV bytes");
    assert!(report.kv_transfer.1 > 0, "batched decode must stage KV as borrowed views");
    // the surviving requests keep decoding normally after the mixed round
    for &id in &ids[..2] {
        engine.decode_step(id).unwrap();
        engine.release(id);
    }
}

/// The `EngineHandle` round-trip for batched rounds, plus fallback
/// equivalence through the channel API. The KV-interchange totals ride
/// ONLY on the batch reply now: the PR-4-era standalone
/// `KvTransferTotals` polling job is deleted from the scheduler-facing
/// surface, so the piggyback must carry live, consistent numbers.
#[test]
fn engine_handle_decode_batch_roundtrip() {
    let engine = EngineHandle::spawn(artifacts()).unwrap();
    let mut rng = Rng::seed_from_u64(52);
    let s = generate(Task::PRe, &mut rng, 96);
    let (id, _) = engine
        .prefill(s.prompt, Policy::Backbone, "balanced".into())
        .unwrap();
    let report = engine.decode_batch(vec![id]).unwrap();
    assert_eq!(report.tokens.len(), 1);
    let tok_batch = *report.tokens[0].as_ref().unwrap();
    // the reply piggyback is the only totals channel: the zero-copy
    // round must report borrowed KV bytes and no clones
    assert_eq!(report.kv_transfer.0, 0, "fast-path round must clone zero KV bytes");
    assert!(report.kv_transfer.1 > 0, "reply must carry the borrowed-KV totals");
    // totals are cumulative: a second round can only grow them
    let report2 = engine.decode_batch(vec![id]).unwrap();
    assert!(report2.kv_transfer.1 > report.kv_transfer.1);
    let tok_serial = engine.decode_step(id).unwrap();
    // greedy continuation stays on one deterministic trajectory
    assert_ne!(tok_batch, u32::MAX);
    assert_ne!(tok_serial, u32::MAX);
    engine.release(id);
}

/// Lifecycle satellite (PR 6): one round holding a repeated id AND an
/// unknown id fails exactly those slots — the first occurrence of the
/// repeated id decodes normally, the duplicate and the unknown id get
/// typed per-slot errors, and survivors' streams stay bit-identical to
/// a clean engine. Pinned on both the batched path and the serial
/// fallback (which previously stepped a duplicate twice, silently
/// advancing the request two tokens in one round).
#[test]
fn duplicate_and_unknown_ids_fail_per_slot_without_corrupting_survivors() {
    let dir = artifacts();
    let mut engine = Engine::load(&dir).unwrap();
    let mut reference = Engine::load(&dir).unwrap();
    let mut rng = Rng::seed_from_u64(55);
    let policy = Policy::Flux { sa_mode: AttnMode::Ssa, decode: DecodeMode::Sparse };

    let mut ids = vec![];
    let mut want: Vec<Vec<u32>> = vec![];
    for task in [Task::PRe, Task::Gov] {
        let s = generate(task, &mut rng, 110);
        let (id, r) = engine.prefill(&s.prompt, &policy, "balanced").unwrap();
        ids.push(id);
        let (rid, rr) = reference.prefill(&s.prompt, &policy, "balanced").unwrap();
        assert_eq!(r.first_token, rr.first_token);
        let mut toks = vec![];
        for _ in 0..4 {
            toks.push(reference.decode_step(rid).unwrap());
        }
        reference.release(rid);
        want.push(toks);
    }

    // poisoned round: [A, B, A again, unknown]
    let round = vec![ids[0], ids[1], ids[0], 9999];
    let mut got: Vec<Vec<u32>> = vec![vec![], vec![]];
    for batched in [true, false] {
        engine.set_batch_decode(batched);
        let report = engine.decode_batch_report(&round);
        assert_eq!(report.batched, batched);
        assert_eq!(report.tokens.len(), 4);
        got[0].push(*report.tokens[0].as_ref().expect("first occurrence must decode"));
        got[1].push(*report.tokens[1].as_ref().expect("batchmate must survive"));
        let dup = report.tokens[2].as_ref().unwrap_err().to_string();
        assert!(dup.contains("duplicate request"), "{dup}");
        let unk = report.tokens[3].as_ref().unwrap_err().to_string();
        assert!(unk.contains("unknown request"), "{unk}");
    }
    engine.set_batch_decode(true);

    // survivors keep decoding on the reference trajectory
    for (si, &id) in ids.iter().enumerate() {
        for _ in 0..2 {
            got[si].push(engine.decode_step(id).unwrap());
        }
        engine.release(id);
    }
    assert_eq!(got, want, "poisoned rounds must not corrupt survivor state");
}

fn start_coordinator(cfg: ServingConfig) -> (std::sync::Arc<Coordinator>, EngineHandle) {
    let engine = EngineHandle::spawn(artifacts()).unwrap();
    let coord = Coordinator::start(engine.clone(), cfg).unwrap();
    (coord, engine)
}

/// Scheduler satellite: mid-round cancellation shrinks the next batch
/// (rounds drop from size 2 to size 1) and frees the engine slot (a
/// third request admits into a 2-slot coordinator and completes); the
/// scheduler issues exactly one DecodeBatch round-trip per decode round
/// (decode_rounds == batch-size samples).
#[test]
fn cancellation_shrinks_next_batch_and_frees_slot() {
    let (coord, engine) =
        start_coordinator(ServingConfig { max_active_requests: 2, ..Default::default() });
    let mut rng = Rng::seed_from_u64(53);
    let sa = generate(Task::PRe, &mut rng, 96);
    let sb = generate(Task::Gov, &mut rng, 96);
    let sc = generate(Task::Trec, &mut rng, 96);

    let ha = coord
        .open(Request { prompt: sa.prompt, max_new: 1024, ignore_eos: true, ..Default::default() })
        .unwrap();
    let hb = coord
        .open(Request { prompt: sb.prompt, max_new: 1024, ignore_eos: true, ..Default::default() })
        .unwrap();
    // both decoding: the rounds are genuinely batched at size 2
    for h in [&ha, &hb] {
        loop {
            match h.recv_timeout(TIMEOUT) {
                Some(SessionEvent::Token { .. }) => break,
                Some(SessionEvent::Error { error }) => panic!("errored early: {error}"),
                Some(_) => {}
                None => panic!("stream closed early"),
            }
        }
    }

    ha.cancel();
    let err = loop {
        match ha.recv_timeout(TIMEOUT) {
            Some(SessionEvent::Error { error }) => break error,
            Some(SessionEvent::Done { .. }) => panic!("cancelled session must not complete"),
            Some(_) => {}
            None => panic!("A closed without a terminal event"),
        }
    };
    assert_eq!(err, RequestError::Cancelled);

    // B alone in the batch now: drain enough post-cancel tokens that at
    // least one size-1 round must have run (more than one scheduler
    // round block past A's retirement)
    let mut post_cancel = 0;
    while post_cancel < 6 {
        match hb.recv_timeout(TIMEOUT) {
            Some(SessionEvent::Token { .. }) => post_cancel += 1,
            Some(SessionEvent::Error { error }) => panic!("B errored: {error}"),
            Some(_) => {}
            None => panic!("B closed early"),
        }
    }
    hb.cancel(); // release B's slot too

    // the freed capacity admits and completes a fresh request
    let resp = coord
        .submit(Request { prompt: sc.prompt, max_new: 3, ignore_eos: true, ..Default::default() })
        .unwrap();
    assert_eq!(resp.tokens.len(), 3);

    let m = coord.metrics.lock().unwrap();
    assert_eq!(m.requests_cancelled, 2);
    assert_eq!(m.requests_completed, 1);
    assert!(m.decode_rounds > 0, "batched rounds must be counted");
    assert_eq!(
        m.decode_rounds,
        m.decode_batch_size.count() as u64,
        "exactly one batch-size sample per DecodeBatch round-trip"
    );
    assert!(
        m.decode_batch_size.percentile_us(100.0) >= 2,
        "A and B must have decoded in shared rounds"
    );
    assert_eq!(
        m.decode_batch_size.percentile_us(0.0),
        1,
        "post-cancel rounds must shrink to the surviving request"
    );
    assert!(m.fa_group_slots > 0, "FA group occupancy must be observable");
    drop(m);
    common::assert_pool_drained(&engine);
}

/// Batched rounds preserve the full streaming contract: stop tokens
/// still truncate inclusively and the streamed order equals the
/// blocking API's tokens (greedy determinism through the batch path).
#[test]
fn batched_rounds_preserve_stop_tokens_and_stream_order() {
    let (coord, engine) = start_coordinator(ServingConfig::default());
    let mut rng = Rng::seed_from_u64(54);
    let s = generate(Task::PRe, &mut rng, 100);
    let base = coord
        .submit(Request {
            prompt: s.prompt.clone(),
            max_new: 8,
            ignore_eos: true,
            ..Default::default()
        })
        .unwrap();
    assert_eq!(base.tokens.len(), 8);

    let stop = base.tokens[3];
    let first_idx = base.tokens.iter().position(|&t| t == stop).unwrap();
    let h = coord
        .open(Request {
            prompt: s.prompt.clone(),
            max_new: 8,
            ignore_eos: true,
            stop_tokens: vec![stop],
            ..Default::default()
        })
        .unwrap();
    let mut streamed = vec![];
    loop {
        match h.recv_timeout(TIMEOUT) {
            Some(SessionEvent::Prefilled { first_token, .. }) => streamed.push(first_token),
            Some(SessionEvent::Token { tok, .. }) => streamed.push(tok),
            Some(SessionEvent::Done { stats }) => {
                assert_eq!(streamed, stats.tokens);
                break;
            }
            Some(SessionEvent::Error { error }) => panic!("stream failed: {error}"),
            Some(_) => {}
            None => panic!("stream closed early"),
        }
    }
    assert_eq!(streamed, base.tokens[..=first_idx].to_vec());
    common::assert_pool_drained(&engine);
}
