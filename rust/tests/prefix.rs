//! Cross-request prefix cache acceptance suite (DESIGN.md §13).
//!
//! Pins the four gates ISSUE 8 names for `kvcache::prefix`:
//! * a warm hit's token stream is BIT-IDENTICAL to the cold run of the
//!   same prompt (the pinned cached route + pool-internal KV copy must
//!   be invisible to the math), under dense AND sparse decode;
//! * a full-prefix hit skips every prefix prefill chunk — only the
//!   suffix runs, visible in `PrefillReport::chunks`,
//!   `cached_prefix_tokens` and the backend's `rows_valid` ledger;
//! * eviction under pool pressure frees pages and NEVER takes a node a
//!   live prefill job holds pinned — the allocation fails typed
//!   instead;
//! * the pool drains back to fully-free once the cache is cleared, for
//!   straight-line runs and for seeded interleavings of hits, misses,
//!   mid-prefill cancels, evictions and clears (the satellite-3
//!   property, wired through `common::assert_pool_drained`).

use std::path::PathBuf;

use flux_attention::engine::{ChunkOutcome, Engine, EngineHandle, PrefillReport};
use flux_attention::router::{AttnMode, DecodeMode, Policy};
use flux_attention::runtime::synthetic;
use flux_attention::util::rng::Rng;

mod common;

const PAGE: usize = Engine::DEFAULT_PAGE_TOKENS;
/// Chunk size used throughout: page-aligned so cold insert boundaries
/// land without clamping, small enough that a 104-token prompt needs
/// several chunks.
const CHUNK: usize = 32;

fn artifacts() -> PathBuf {
    synthetic::ensure_default().expect("artifact generation must not fail")
}

/// Deterministic prompt: `shared_pages` full pages of shared prefix
/// (the cacheable run) followed by a short suffix derived from `salt`.
/// The suffix stays under one page so every prompt built from the same
/// `shared_pages` inserts and hits the exact same page-aligned prefix.
fn prompt_with_suffix(shared_pages: usize, salt: u32) -> Vec<u32> {
    let mut p: Vec<u32> = (0..shared_pages * PAGE).map(|i| (i as u32 * 7) % 500 + 1).collect();
    p.extend((0..8u32).map(|k| (salt.wrapping_mul(53) + k * 37) % 500 + 1));
    p
}

/// Run a full chunked prefill to `Done`.
fn chunked(e: &mut Engine, prompt: &[u32], policy: &Policy) -> (u64, PrefillReport) {
    let job = e.prefill_open(prompt, policy, "balanced", CHUNK).expect("prefill_open");
    loop {
        if let ChunkOutcome::Done { id, report } = e.prefill_chunk(job).expect("prefill_chunk") {
            return (id, report);
        }
    }
}

/// Chunked prefill + `n_decode` greedy steps; releases the request and
/// returns the full stream (first token + decode tokens) and report.
fn stream(
    e: &mut Engine,
    prompt: &[u32],
    policy: &Policy,
    n_decode: usize,
) -> (Vec<u32>, PrefillReport) {
    let (id, report) = chunked(e, prompt, policy);
    let mut toks = vec![report.first_token];
    for _ in 0..n_decode {
        toks.push(e.decode_step(id).expect("decode_step"));
    }
    e.release(id);
    (toks, report)
}

/// Gate (a), dense decode: the warm-hit stream must be byte-identical
/// to the cold-start stream of the same prompt, and to a run on an
/// engine with the cache disabled (the cache path must not perturb the
/// math in either direction).
#[test]
fn warm_hit_stream_is_bit_identical_to_cold_dense() {
    let policy = Policy::Flux { sa_mode: AttnMode::Ssa, decode: DecodeMode::Dense };
    let prompt = prompt_with_suffix(3, 1); // 96 shared + 8 suffix

    let mut off = Engine::load(&artifacts()).unwrap();
    let (reference, off_report) = stream(&mut off, &prompt, &policy, 8);
    assert_eq!(off_report.cached_prefix_tokens, 0, "the cache starts disabled");

    let mut e = Engine::load(&artifacts()).unwrap();
    e.set_prefix_cache(true, None);
    let (cold, cold_report) = stream(&mut e, &prompt, &policy, 8);
    assert_eq!(cold_report.cached_prefix_tokens, 0, "first run must be cold");
    assert_eq!(cold, reference, "an enabled-but-empty cache must not change the stream");

    let (warm, warm_report) = stream(&mut e, &prompt, &policy, 8);
    assert_eq!(warm_report.cached_prefix_tokens, 3 * PAGE, "the warm run must hit the cache");
    assert_eq!(warm, cold, "warm-hit stream diverged from the cold run");

    // a different suffix over the same shared prefix also hits, and its
    // own cold reference (cache off) matches bit-for-bit
    let prompt2 = prompt_with_suffix(3, 2);
    let (warm2, warm2_report) = stream(&mut e, &prompt2, &policy, 8);
    assert_eq!(warm2_report.cached_prefix_tokens, 3 * PAGE);
    let (ref2, _) = stream(&mut off, &prompt2, &policy, 8);
    assert_eq!(warm2, ref2, "warm stream under a new suffix diverged from its cold reference");

    let stats = e.prefix_stats();
    assert_eq!(stats.hits, 2);
    assert_eq!(stats.misses, 1);
    assert_eq!(stats.tokens_reused, 2 * 3 * PAGE as u64);
    e.prefix_clear();
    e.pool().drained().expect("pool must drain after clear");
}

/// Gate (a), sparse decode: ring snapshots captured at the insert
/// boundary must restore to the exact decode state the cold run had —
/// streams stay bit-identical through the sparse ring path too.
#[test]
fn warm_hit_stream_is_bit_identical_to_cold_sparse() {
    let policy = Policy::Flux { sa_mode: AttnMode::Ssa, decode: DecodeMode::Sparse };
    let prompt = prompt_with_suffix(3, 3);

    let mut e = Engine::load(&artifacts()).unwrap();
    e.set_prefix_cache(true, None);
    let (cold, cold_report) = stream(&mut e, &prompt, &policy, 8);
    assert_eq!(cold_report.cached_prefix_tokens, 0);

    let (warm, warm_report) = stream(&mut e, &prompt, &policy, 8);
    assert_eq!(
        warm_report.cached_prefix_tokens,
        3 * PAGE,
        "sparse-decode endpoint must be usable (ring snapshots stored)"
    );
    assert_eq!(warm, cold, "sparse-decode warm stream diverged from the cold run");
    assert_eq!(warm_report.modes, cold_report.modes, "the hit must pin the stored route");

    e.prefix_clear();
    e.pool().drained().expect("pool must drain after clear");
}

/// Gate (b): a full-prefix hit runs only the suffix — one chunk instead
/// of the cold run's four, `cached_prefix_tokens` covering the shared
/// pages, and the backend's valid-row ledger showing the prefix rows
/// were never recomputed.
#[test]
fn full_prefix_hit_skips_prefix_chunks() {
    let policy = Policy::Flux { sa_mode: AttnMode::Ssa, decode: DecodeMode::Dense };
    let prompt = prompt_with_suffix(3, 4); // 104 tokens → 4 chunks of 32 cold

    let mut e = Engine::load(&artifacts()).unwrap();
    e.set_prefix_cache(true, None);

    let (v0, _) = e.prefill_row_totals();
    let (cold_id, cold_report) = chunked(&mut e, &prompt, &policy);
    let (v1, _) = e.prefill_row_totals();
    e.release(cold_id);
    let cold_rows = v1 - v0;
    assert!(cold_report.chunks >= 2, "the cold run must be genuinely chunked");
    assert!(cold_rows > 0);

    let (warm_id, warm_report) = chunked(&mut e, &prompt, &policy);
    let (v2, _) = e.prefill_row_totals();
    e.release(warm_id);
    let warm_rows = v2 - v1;
    assert_eq!(warm_report.chunks, 1, "a full-prefix hit must run only the suffix chunk");
    assert_eq!(warm_report.cached_prefix_tokens, 3 * PAGE);
    assert!(warm_rows > 0, "the suffix chunk still computes real rows");
    assert!(
        warm_rows < cold_rows / 2,
        "warm run recomputed prefix rows: {warm_rows} valid rows vs {cold_rows} cold"
    );

    e.prefix_clear();
    e.pool().drained().expect("pool must drain after clear");
}

/// Gate (c): under pool pressure `evict_for` reclaims unpinned cached
/// prefixes but never one a live prefill job holds pinned — the
/// allocation fails typed while the pin is held, succeeds after it
/// drops, and the capacity budget then evicts the LRU entry to admit
/// the next insert.
#[test]
fn eviction_frees_pages_and_never_takes_pinned_nodes() {
    // Synthetic geometry (4 layers, 32-token pages): a 104-token prompt
    // buckets to 128 → 16 staging pages; a 96-token prefix retains 12.
    // 36 total pages fit one live job + one cached prefix but NOT a
    // second concurrent staging allocation; capacity 12 fits exactly
    // one cached prefix, so a second insert must evict the first.
    let mut e = Engine::load_with_pool(&artifacts(), Some((PAGE, 36 * PAGE))).unwrap();
    assert_eq!(e.pool().total_pages(), 36);
    e.set_prefix_cache(true, Some(12));
    let modes = vec![AttnMode::Fa, AttnMode::Ssa, AttnMode::Fa, AttnMode::Ssa];
    let policy = Policy::Static { modes, decode: DecodeMode::Dense };

    // seed prefix A (96 tokens = 12 pages retained)
    let prompt_a = prompt_with_suffix(3, 10);
    let (id, report) = chunked(&mut e, &prompt_a, &policy);
    assert_eq!(report.cached_prefix_tokens, 0);
    e.release(id);
    assert_eq!(e.prefix_retained_pages(), 12);

    // open (but do not run) a warm job on A: the hit pins the node for
    // the job's whole lifetime
    let warm_prompt = prompt_with_suffix(3, 11);
    let warm_job = e.prefill_open(&warm_prompt, &policy, "balanced", CHUNK).unwrap();
    assert_eq!(e.pool().pages_free(), 36 - 16 - 12, "warm staging + retained prefix");

    // a second cold open needs 16 staging pages but only 8 are free;
    // the only evictable candidate is pinned, so the open must fail
    // typed — and must NOT have stolen the pinned pages
    let prompt_b = {
        let mut p: Vec<u32> = (0..3 * PAGE).map(|i| (i as u32 * 11) % 500 + 1).collect();
        p.extend([9, 9, 9, 9, 9, 9, 9, 9]);
        p
    };
    let err = e.prefill_open(&prompt_b, &policy, "balanced", CHUNK);
    assert!(err.is_err(), "pool pressure with only pinned nodes must fail the allocation");
    assert_eq!(e.prefix_stats().evictions, 0, "a pinned node must never be evicted");
    assert_eq!(e.prefix_retained_pages(), 12, "the pinned prefix kept its pages");

    // the pinned job still completes correctly off the cached pages
    let (warm_id, warm_report) = loop {
        if let ChunkOutcome::Done { id, report } = e.prefill_chunk(warm_job).unwrap() {
            break (id, report);
        }
    };
    assert_eq!(warm_report.cached_prefix_tokens, 3 * PAGE);
    e.release(warm_id); // pin dropped with the job; request pages freed

    // now the same open succeeds, and its insert evicts LRU prefix A
    // under the 12-page capacity budget — freeing pages for real
    let (b_id, b_report) = chunked(&mut e, &prompt_b, &policy);
    assert_eq!(b_report.cached_prefix_tokens, 0);
    e.release(b_id);
    let stats = e.prefix_stats();
    assert_eq!(stats.evictions, 1, "inserting B past capacity must evict A");
    assert_eq!(e.prefix_retained_pages(), 12, "only B's prefix remains retained");

    // B is cached (warm hit) while A was evicted (cold again). Order
    // matters: A2's completion re-inserts A's prefix, which under the
    // one-entry capacity budget evicts B in turn — so probe B first.
    let warm_b = {
        let mut p = prompt_b.clone();
        let n = p.len();
        p[n - 1] ^= 1;
        p
    };
    let (b2_id, b2_report) = chunked(&mut e, &warm_b, &policy);
    e.release(b2_id);
    assert_eq!(b2_report.cached_prefix_tokens, 3 * PAGE, "the surviving prefix must hit");
    let (a2_id, a2_report) = chunked(&mut e, &prompt_a, &policy);
    e.release(a2_id);
    assert_eq!(a2_report.cached_prefix_tokens, 0, "the evicted prefix must miss");

    // gate (d) on the small pool: clear releases every retained page
    e.prefix_clear();
    e.pool().drained().expect("pool must drain to zero after cache clear");
}

/// Satellite 3: seeded interleavings of hit/miss runs, mid-prefill
/// cancels, capacity evictions and index clears always leave the pool
/// fully drained once the cache is cleared and every request released —
/// checked through the shared `common::assert_pool_drained` helper the
/// rest of the integration suite uses.
#[test]
fn interleaved_schedules_always_drain_the_pool() {
    let engine = EngineHandle::spawn(artifacts()).unwrap();
    // capacity 24 pages ≈ two 96-token prefixes: the third distinct
    // insert forces an eviction, so schedules exercise that path too
    engine.set_prefix_cache(true, Some(24)).unwrap();
    let policies = [
        Policy::Flux { sa_mode: AttnMode::Ssa, decode: DecodeMode::Dense },
        Policy::Flux { sa_mode: AttnMode::Ssa, decode: DecodeMode::Sparse },
    ];

    for seed in 0..3u64 {
        let mut rng = Rng::seed_from_u64(0xF1 + seed);
        for op in 0..20 {
            let shared_pages = 2 + rng.gen_range(3); // 64/96/128-token shared runs
            let salt = (seed * 1000 + op) as u32;
            let mut prompt = prompt_with_suffix(shared_pages, salt);
            // occasionally extend past one page so inserts split/nest
            for _ in 0..rng.gen_range(3) * 16 {
                prompt.push(rng.range_u32(1, 500));
            }
            let policy = &policies[rng.gen_range(2)];
            match rng.gen_range(10) {
                // mid-prefill cancel: open, run 0-1 chunks, drop the job
                // (a warm full-prefix hit can finish in its first chunk
                // — release the promoted request instead)
                0 | 1 => {
                    let job = engine
                        .prefill_open(prompt, policy.clone(), "balanced".into(), CHUNK)
                        .unwrap();
                    if rng.gen_range(2) == 1 {
                        match engine.prefill_chunk(job).unwrap() {
                            ChunkOutcome::Done { id, .. } => engine.release(id),
                            ChunkOutcome::More { .. } => engine.prefill_cancel(job),
                        }
                    } else {
                        engine.prefill_cancel(job);
                    }
                }
                // index clear with whatever is pinned/retained right now
                2 => engine.prefix_clear().unwrap(),
                // ordinary run: prefill (hit or miss), a few decode
                // steps, release
                _ => {
                    let job = engine
                        .prefill_open(prompt, policy.clone(), "balanced".into(), CHUNK)
                        .unwrap();
                    let id = loop {
                        if let ChunkOutcome::Done { id, .. } = engine.prefill_chunk(job).unwrap() {
                            break id;
                        }
                    };
                    for _ in 0..rng.gen_range(3) {
                        engine.decode_step(id).unwrap();
                    }
                    engine.release(id);
                }
            }
            let stats = engine.prefix_stats().unwrap();
            assert!(
                stats.retained_pages <= 24,
                "seed {seed} op {op}: the capacity budget must bound retention, got {} pages",
                stats.retained_pages
            );
        }
        engine.prefix_clear().unwrap();
        common::assert_pool_drained(&engine);
    }
    let stats = engine.prefix_stats().unwrap();
    assert!(stats.hits + stats.misses > 0, "the schedules must have exercised the cache");
}
