//! Failure-domain property suite (DESIGN.md §12): deterministic fault
//! injection through [`flux_attention::runtime::chaos::ChaosBackend`]
//! drives the engine supervision, round-watchdog and graceful-drain
//! machinery end to end.
//!
//! Invariants pinned here, per ISSUE 7's acceptance gates:
//! * every opened session sees EXACTLY ONE typed terminal event
//!   (`Done` or a typed `RequestError`), never a silent stream close;
//! * the scheduler never hangs — every wait is bounded by `TIMEOUT`;
//! * a kernel `Err` fails one request, a kernel panic fails the engine
//!   lifetime (supervision respawns it), a stall trips the watchdog;
//! * the KV pool drains back to fully-free after recovery;
//! * surviving and post-restart streams are bit-identical to fault-free
//!   runs (greedy decode + fault-free respawn ⇒ determinism).
//!
//! Fault plans are constructed programmatically — mutating
//! `FLUX_FAULT_PLAN`/`FLUX_FAULT_SEED` would race across parallel test
//! threads. The seeded sweep only READS `FLUX_FAULT_SEED` as its base
//! seed so CI can run the same suite across many schedules.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use flux_attention::config::{AdmissionMode, ServingConfig};
use flux_attention::coordinator::{
    Coordinator, Request, RequestError, Response, SessionEvent, SessionHandle,
};
use flux_attention::engine::EngineHandle;
use flux_attention::router::{AttnMode, DecodeMode, Policy};
use flux_attention::runtime::chaos::{FaultKind, FaultPlan};
use flux_attention::runtime::synthetic;
use flux_attention::util::rng::Rng;
use flux_attention::workload::{generate, Task};

mod common;

const TIMEOUT: Duration = Duration::from_secs(120);

fn artifacts() -> PathBuf {
    synthetic::ensure_default().expect("artifact generation must not fail")
}

fn start_coordinator(cfg: ServingConfig) -> (Arc<Coordinator>, EngineHandle) {
    let engine = EngineHandle::spawn(artifacts()).unwrap();
    let coord = Coordinator::start(engine.clone(), cfg).unwrap();
    (coord, engine)
}

/// Everything one session's event stream produced, drained until the
/// stream closed. Each receive is bounded by `TIMEOUT`, so a wedged
/// scheduler fails the test instead of hanging it.
struct StreamOutcome {
    /// `Prefilled.first_token` followed by every `Token` event.
    tokens: Vec<u32>,
    done: Option<Response>,
    error: Option<RequestError>,
    /// Count of terminal events seen — the exactly-one invariant.
    terminals: usize,
    /// `Preempted` events seen (non-terminal, DESIGN.md §15).
    preempted: usize,
    /// `Resumed` events seen (non-terminal, DESIGN.md §15).
    resumed: usize,
}

fn drain_session(h: &SessionHandle) -> StreamOutcome {
    let mut out = StreamOutcome {
        tokens: vec![],
        done: None,
        error: None,
        terminals: 0,
        preempted: 0,
        resumed: 0,
    };
    while let Some(ev) = h.recv_timeout(TIMEOUT) {
        match ev {
            SessionEvent::Queued => {}
            SessionEvent::Prefilled { first_token, .. } => out.tokens.push(first_token),
            SessionEvent::Token { tok, .. } => out.tokens.push(tok),
            SessionEvent::Preempted { .. } => out.preempted += 1,
            SessionEvent::Resumed { .. } => out.resumed += 1,
            SessionEvent::Done { stats } => {
                out.terminals += 1;
                out.done = Some(stats);
            }
            SessionEvent::Error { error } => {
                out.terminals += 1;
                out.error = Some(error);
            }
        }
    }
    out
}

/// A kernel panic mid-workload kills the engine lifetime: every
/// in-flight session retires with a typed retryable `EngineFailed`,
/// supervision respawns the engine, and the SAME prompts then decode
/// bit-identically to a fault-free run — the tentpole recovery gate.
#[test]
fn injected_panic_recovers_with_bit_identical_restart() {
    let mut rng = Rng::seed_from_u64(71);
    let prompts: Vec<Vec<u32>> =
        (0..3).map(|_| generate(Task::PRe, &mut rng, 96).prompt).collect();
    let req = |prompt: Vec<u32>| Request { prompt, max_new: 12, ignore_eos: true, ..Default::default() };

    // fault-free reference tokens for every prompt (greedy ⇒ deterministic)
    let (clean, clean_engine) = start_coordinator(ServingConfig::default());
    let reference: Vec<Vec<u32>> =
        prompts.iter().map(|p| clean.submit(req(p.clone())).unwrap().tokens).collect();
    common::assert_pool_drained(&clean_engine);

    // engine lifetime 0 panics at backend call 60 — inside the workload
    // (three 12-token streams need hundreds of calls), never after it
    let plan = FaultPlan::new().with(60, FaultKind::Panic);
    let engine = EngineHandle::spawn_with_faults(artifacts(), None, plan).unwrap();
    let coord = Coordinator::start(
        engine.clone(),
        ServingConfig { engine_restart_backoff_ms: 10, ..Default::default() },
    )
    .unwrap();
    let handles: Vec<SessionHandle> =
        prompts.iter().map(|p| coord.open(req(p.clone())).unwrap()).collect();
    let outcomes: Vec<StreamOutcome> = handles.iter().map(drain_session).collect();

    let mut failed = 0;
    for (i, o) in outcomes.iter().enumerate() {
        assert_eq!(o.terminals, 1, "session {i} must see exactly one terminal event");
        match (&o.done, &o.error) {
            (Some(done), None) => {
                // a surviving stream is bit-identical to the fault-free run
                assert_eq!(o.tokens, reference[i], "session {i}: surviving stream diverged");
                assert_eq!(done.tokens, reference[i]);
            }
            (None, Some(err)) => {
                failed += 1;
                assert!(
                    matches!(err, RequestError::EngineFailed { .. }),
                    "session {i}: a panic must surface as EngineFailed, got {err:?}"
                );
                assert!(err.retryable(), "EngineFailed must be marked retryable");
            }
            other => panic!("session {i}: inconsistent terminal state {other:?}"),
        }
    }
    assert!(failed >= 1, "the injected panic must fail at least one in-flight session");

    // post-restart: the same prompts on the respawned (fault-free)
    // engine reproduce the reference streams exactly
    for (p, want) in prompts.iter().zip(&reference) {
        let got = coord.submit(req(p.clone())).unwrap();
        assert_eq!(got.tokens, *want, "post-restart stream must be bit-identical");
    }
    let m = coord.metrics.lock().unwrap();
    assert!(m.engine_restarts >= 1, "supervision must have restarted the engine");
    assert!(m.requests_failed >= 1);
    drop(m);
    assert!(engine.generation() >= 1, "respawn must bump the engine generation");
    common::assert_pool_drained(&engine);
}

/// A kernel `Err` is a PER-REQUEST failure: the victim retires with a
/// typed non-retryable `RequestError::Engine`, the engine lifetime
/// survives (no restart, generation unchanged), and the next request
/// decodes bit-identically to a fault-free run.
#[test]
fn kernel_err_fails_one_request_and_spares_the_engine() {
    let mut rng = Rng::seed_from_u64(72);
    let prompt = generate(Task::Gov, &mut rng, 96).prompt;
    let req = |max_new: usize| Request {
        prompt: prompt.clone(),
        max_new,
        ignore_eos: true,
        ..Default::default()
    };

    let (clean, clean_engine) = start_coordinator(ServingConfig::default());
    let reference = clean.submit(req(6)).unwrap().tokens;
    common::assert_pool_drained(&clean_engine);

    // call 40 lands mid-decode of the lone victim (its prefill takes
    // ~9 calls, each decode round ~17)
    let plan = FaultPlan::new().with(40, FaultKind::Err);
    let engine = EngineHandle::spawn_with_faults(artifacts(), None, plan).unwrap();
    let coord = Coordinator::start(engine.clone(), ServingConfig::default()).unwrap();

    let h = coord.open(req(16)).unwrap();
    let o = drain_session(&h);
    assert_eq!(o.terminals, 1, "the victim must see exactly one terminal event");
    let err = o.error.expect("the victim must retire with a typed error");
    assert!(
        matches!(err, RequestError::Engine(_)),
        "a kernel Err is a per-request failure, got {err:?}"
    );
    assert!(!err.retryable(), "per-request engine failures are not retryable");

    // the engine lifetime survived the fault: same prompt, same tokens
    let got = coord.submit(req(6)).unwrap();
    assert_eq!(got.tokens, reference, "the surviving engine must stay deterministic");
    let m = coord.metrics.lock().unwrap();
    assert_eq!(m.engine_restarts, 0, "a kernel Err must not trigger supervision");
    assert_eq!(m.watchdog_trips, 0);
    assert_eq!(m.requests_failed, 1);
    drop(m);
    assert_eq!(engine.generation(), 0);
    common::assert_pool_drained(&engine);
}

/// A stalled round trips the watchdog instead of hanging the scheduler:
/// the wedged lifetime is classified stalled (typed cause names the
/// watchdog), counted in `watchdog_trips`, and supervision restarts the
/// engine — after which decoding is bit-identical to a fault-free run.
#[test]
fn stalled_round_trips_watchdog_and_restarts() {
    let mut rng = Rng::seed_from_u64(73);
    let prompt = generate(Task::PRe, &mut rng, 48).prompt;
    let req = |max_new: usize| Request {
        prompt: prompt.clone(),
        max_new,
        ignore_eos: true,
        ..Default::default()
    };

    let (clean, clean_engine) = start_coordinator(ServingConfig::default());
    let reference = clean.submit(req(6)).unwrap().tokens;
    common::assert_pool_drained(&clean_engine);

    // an 8s stall against a 1.5s round watchdog: the trip is
    // deterministic, while legitimate rounds on this tiny synthetic
    // model stay far under the deadline
    let plan = FaultPlan::new().with(40, FaultKind::Stall(8_000));
    let engine = EngineHandle::spawn_with_faults(artifacts(), None, plan).unwrap();
    let coord = Coordinator::start(
        engine.clone(),
        ServingConfig {
            engine_round_timeout_ms: Some(1_500),
            engine_restart_backoff_ms: 10,
            ..Default::default()
        },
    )
    .unwrap();

    let h = coord.open(req(16)).unwrap();
    let o = drain_session(&h);
    assert_eq!(o.terminals, 1, "the stalled session must see exactly one terminal event");
    match o.error.expect("the stalled session must retire with a typed error") {
        RequestError::EngineFailed { cause, .. } => {
            assert!(cause.contains("watchdog"), "stall must be classified by the watchdog: {cause}");
        }
        other => panic!("a tripped watchdog must surface as EngineFailed, got {other:?}"),
    }

    // post-restart bit-identity + the supervision counters
    let got = coord.submit(req(6)).unwrap();
    assert_eq!(got.tokens, reference, "post-restart stream must be bit-identical");
    let m = coord.metrics.lock().unwrap();
    assert!(m.watchdog_trips >= 1, "the stall must be counted as a watchdog trip");
    assert!(m.engine_restarts >= 1, "a stalled engine must be restarted");
    drop(m);
    assert!(engine.generation() >= 1);
    common::assert_pool_drained(&engine);
}

/// Property sweep over seeded fault schedules: whatever mix of errs,
/// panics, stalls and pool-exhaustion faults a seed draws, every
/// session ends in exactly one typed terminal event within bounded
/// time, the pipeline recovers (a fresh probe is served after at most a
/// few typed-failure retries), and the KV pool drains fully-free.
/// `FLUX_FAULT_SEED` (read-only here) shifts the base seed so CI can
/// sweep many schedules with one binary.
#[test]
fn seeded_fault_schedules_terminate_every_session_exactly_once() {
    let base: u64 = std::env::var("FLUX_FAULT_SEED")
        .ok()
        .and_then(|v| v.trim().parse().ok())
        .unwrap_or(1);
    for seed in base..base + 8 {
        let plan = FaultPlan::seeded(seed);
        let spec = plan.to_string();
        let engine = EngineHandle::spawn_with_faults(artifacts(), None, plan).unwrap();
        let coord = Coordinator::start(
            engine.clone(),
            ServingConfig {
                // generous watchdog: seeded stalls (≤900ms) delay a round
                // without tripping it, while a genuinely wedged round
                // still would — the sweep stays bounded either way
                engine_round_timeout_ms: Some(30_000),
                // seeded plans carry at most 3 faults, and a lifetime's
                // remaining faults die with it on respawn
                engine_restart_max: 4,
                engine_restart_backoff_ms: 10,
                ..Default::default()
            },
        )
        .unwrap();
        let mut rng = Rng::seed_from_u64(seed);
        let reqs: Vec<Request> = (0..3)
            .map(|_| {
                let len = 64 + rng.gen_range(64);
                let max_new = 6 + rng.gen_range(8);
                Request {
                    prompt: generate(Task::PRe, &mut rng, len).prompt,
                    max_new,
                    policy: Policy::Flux { sa_mode: AttnMode::Ssa, decode: DecodeMode::Dense },
                    ignore_eos: true,
                    ..Default::default()
                }
            })
            .collect();
        let handles: Vec<SessionHandle> =
            reqs.iter().map(|r| coord.open(r.clone()).unwrap()).collect();
        for (i, h) in handles.iter().enumerate() {
            let o = drain_session(h);
            assert_eq!(
                o.terminals, 1,
                "seed {seed} (plan {spec}): session {i} must see exactly one terminal event"
            );
            if let Some(err) = &o.error {
                assert!(
                    matches!(err, RequestError::Engine(_) | RequestError::EngineFailed { .. }),
                    "seed {seed} (plan {spec}): session {i} got a mistyped terminal {err:?}"
                );
            } else {
                let done = o.done.as_ref().expect("terminals == 1 but no terminal recorded");
                assert_eq!(
                    done.tokens.len(),
                    reqs[i].max_new,
                    "seed {seed}: a completed stream must honor max_new"
                );
                assert_eq!(o.tokens, done.tokens, "seed {seed}: events must mirror Done stats");
            }
        }
        // recovery liveness: unfired faults burn off across at most a
        // few typed failures (respawns are fault-free), then the
        // pipeline serves again. The restart budget (4) outlasts the
        // at-most-one lifetime-killing fault a plan can land, so the
        // scheduler is still admitting here.
        let probe = Request {
            prompt: generate(Task::Gov, &mut rng, 48).prompt,
            max_new: 4,
            ignore_eos: true,
            ..Default::default()
        };
        let mut served = None;
        for _ in 0..5 {
            let h = coord
                .open(probe.clone())
                .unwrap_or_else(|e| panic!("seed {seed} (plan {spec}): probe admission failed: {e:?}"));
            let o = drain_session(&h);
            assert_eq!(
                o.terminals, 1,
                "seed {seed} (plan {spec}): the probe must see exactly one terminal event"
            );
            match o.error {
                Some(err) => assert!(
                    matches!(err, RequestError::Engine(_) | RequestError::EngineFailed { .. }),
                    "seed {seed} (plan {spec}): probe got a mistyped terminal {err:?}"
                ),
                None => {
                    served = o.done;
                    break;
                }
            }
        }
        let served =
            served.unwrap_or_else(|| panic!("seed {seed} (plan {spec}): pipeline did not recover"));
        assert_eq!(served.tokens.len(), 4);
        common::assert_pool_drained(&engine);
    }
}

/// Satellite-(c) sweep (DESIGN.md §15): seeded schedules with GUARANTEED
/// `pool@N` faults, run under `Optimistic` admission, so the
/// preempt-park-resume machinery is exercised on every seed on top of
/// whatever errs/panics/stalls the seed drew. Invariants: every session
/// still terminates exactly once (typed — pool pressure may surface as
/// the retryable `PreemptionExhausted` but never a silent close or a
/// decode-phase `Overloaded`), the pipeline recovers, the sweep lands at
/// least one preemption, and the pool drains fully-free afterwards.
#[test]
fn seeded_pool_faults_under_optimistic_admission_terminate_and_drain() {
    let base: u64 = std::env::var("FLUX_FAULT_SEED")
        .ok()
        .and_then(|v| v.trim().parse().ok())
        .unwrap_or(1);
    let mut total_preemptions = 0u64;
    for seed in base..base + 4 {
        // the seeded schedule plus two pinned pool faults: call 40 lands
        // inside the first request's decode, call 120 deep in the
        // workload — every seed provokes the preemption path unless an
        // earlier seeded panic kills the lifetime first (also fine: the
        // respawn is fault-free and the sweep still terminates typed)
        let plan = FaultPlan::seeded(seed)
            .with(40, FaultKind::PoolExhausted)
            .with(120, FaultKind::PoolExhausted);
        let spec = plan.to_string();
        let engine = EngineHandle::spawn_with_faults(artifacts(), None, plan).unwrap();
        let coord = Coordinator::start(
            engine.clone(),
            ServingConfig {
                admission_mode: AdmissionMode::Optimistic { factor: 0.5 },
                max_preemptions: 8,
                engine_round_timeout_ms: Some(30_000),
                engine_restart_max: 4,
                engine_restart_backoff_ms: 10,
                ..Default::default()
            },
        )
        .unwrap();
        let mut rng = Rng::seed_from_u64(seed);
        let reqs: Vec<Request> = (0..3)
            .map(|_| {
                let len = 64 + rng.gen_range(64);
                let max_new = 6 + rng.gen_range(8);
                Request {
                    prompt: generate(Task::PRe, &mut rng, len).prompt,
                    max_new,
                    ignore_eos: true,
                    ..Default::default()
                }
            })
            .collect();
        let handles: Vec<SessionHandle> =
            reqs.iter().map(|r| coord.open(r.clone()).unwrap()).collect();
        for (i, h) in handles.iter().enumerate() {
            let o = drain_session(h);
            assert_eq!(
                o.terminals, 1,
                "seed {seed} (plan {spec}): session {i} must see exactly one terminal event"
            );
            if let Some(err) = &o.error {
                assert!(
                    matches!(
                        err,
                        RequestError::Engine(_)
                            | RequestError::EngineFailed { .. }
                            | RequestError::PreemptionExhausted { .. }
                    ),
                    "seed {seed} (plan {spec}): session {i} got a mistyped terminal {err:?}"
                );
            } else {
                let done = o.done.as_ref().expect("terminals == 1 but no terminal recorded");
                assert_eq!(
                    done.tokens.len(),
                    reqs[i].max_new,
                    "seed {seed}: a completed stream must honor max_new"
                );
                assert_eq!(o.tokens, done.tokens, "seed {seed}: events must mirror Done stats");
                assert_eq!(
                    o.preempted, o.resumed,
                    "seed {seed}: a COMPLETED stream must have resumed every preemption"
                );
            }
        }
        // recovery liveness, as in the base sweep
        let probe = Request {
            prompt: generate(Task::Gov, &mut rng, 48).prompt,
            max_new: 4,
            ignore_eos: true,
            ..Default::default()
        };
        let mut served = None;
        for _ in 0..5 {
            let h = coord
                .open(probe.clone())
                .unwrap_or_else(|e| panic!("seed {seed} (plan {spec}): probe admission failed: {e:?}"));
            let o = drain_session(&h);
            assert_eq!(
                o.terminals, 1,
                "seed {seed} (plan {spec}): the probe must see exactly one terminal event"
            );
            match o.error {
                Some(err) => assert!(
                    err.retryable() || matches!(err, RequestError::Engine(_)),
                    "seed {seed} (plan {spec}): probe got a mistyped terminal {err:?}"
                ),
                None => {
                    served = o.done;
                    break;
                }
            }
        }
        let served =
            served.unwrap_or_else(|| panic!("seed {seed} (plan {spec}): pipeline did not recover"));
        assert_eq!(served.tokens.len(), 4);
        total_preemptions += coord.metrics.lock().unwrap().preemptions;
        common::assert_pool_drained(&engine);
    }
    assert!(
        total_preemptions >= 1,
        "the pinned pool@40/pool@120 faults must land at least one preemption across the sweep"
    );
}

/// Graceful drain: in-flight streams run to a full `Done` (never a
/// drain-induced error), new admissions are rejected with the typed
/// retryable `Draining`, and the call is idempotent.
#[test]
fn drain_finishes_inflight_streams_and_rejects_new_admissions() {
    let (coord, engine) = start_coordinator(ServingConfig::default());
    let mut rng = Rng::seed_from_u64(74);
    let s = generate(Task::PRe, &mut rng, 128);
    let h = coord
        .open(Request {
            prompt: s.prompt.clone(),
            max_new: 40,
            ignore_eos: true,
            ..Default::default()
        })
        .unwrap();
    // wait until the stream is genuinely in flight before draining
    loop {
        match h.recv_timeout(TIMEOUT) {
            Some(SessionEvent::Queued) => {}
            Some(SessionEvent::Prefilled { .. }) | Some(SessionEvent::Token { .. }) => break,
            Some(ev) => panic!("unexpected event before the drain: {ev:?}"),
            None => panic!("stream closed before prefill"),
        }
    }
    assert!(!coord.is_draining());
    assert!(coord.drain(Duration::from_secs(60)), "drain must complete within the deadline");
    assert!(coord.is_draining());

    // the in-flight stream finished normally — exactly one Done, all
    // 40 tokens, no drain-induced error
    let o = drain_session(&h);
    assert_eq!(o.terminals, 1, "the draining stream must see exactly one terminal event");
    assert!(o.error.is_none(), "drain must never error an in-flight stream: {:?}", o.error);
    let done = o.done.expect("drain must let the in-flight stream finish");
    assert_eq!(done.tokens.len(), 40);
    assert_eq!(coord.metrics.lock().unwrap().requests_completed, 1);

    // new admissions are rejected synchronously with the typed,
    // retryable drain error
    let err = coord
        .open(Request { prompt: s.prompt, max_new: 2, ..Default::default() })
        .unwrap_err();
    assert_eq!(err, RequestError::Draining);
    assert!(err.retryable(), "Draining must be marked retryable (another replica may serve)");

    // idempotent: the scheduler is already done
    assert!(coord.drain(Duration::from_millis(100)));
    // the engine was shut down by the drain; its pool died with it, so
    // there is nothing to assert drained here
    drop(engine);
}

/// A head-of-line request PARKED on batch budgets must be failed when
/// the drain starts — with the same typed retryable `Draining` a queued
/// arrival gets — instead of sitting in the parked slot until the
/// in-flight set retires (the pre-fix behavior left it stranded past
/// the drain deadline). The in-flight stream still finishes normally.
#[test]
fn drain_rejects_parked_head_of_line_request() {
    let mut rng = Rng::seed_from_u64(76);
    let a_prompt = generate(Task::PRe, &mut rng, 96).prompt;
    let b_prompt = generate(Task::Gov, &mut rng, 96).prompt;
    // the token budget admits A's worst case (96 + 200) alone but not
    // A + B (96 + 8) together, so B parks behind A instead of batching
    let (coord, engine) = start_coordinator(ServingConfig {
        max_batch_total_tokens: 320,
        ..Default::default()
    });
    let ha = coord
        .open(Request { prompt: a_prompt, max_new: 200, ignore_eos: true, ..Default::default() })
        .unwrap();
    // wait until A is genuinely in flight so B cannot co-admit
    loop {
        match ha.recv_timeout(TIMEOUT) {
            Some(SessionEvent::Queued) => {}
            Some(SessionEvent::Prefilled { .. }) | Some(SessionEvent::Token { .. }) => break,
            Some(ev) => panic!("unexpected event before the drain: {ev:?}"),
            None => panic!("stream A closed before prefill"),
        }
    }
    let hb = coord
        .open(Request { prompt: b_prompt, max_new: 8, ignore_eos: true, ..Default::default() })
        .unwrap();
    // wait until the scheduler has pulled B off the queue — with A
    // holding the token budget, B is now sitting in the parked slot
    // (A still has ~200 decode rounds to stream, so it cannot have
    // retired and re-admitted B this early)
    let deadline = std::time::Instant::now() + TIMEOUT;
    while coord.queue_depth() > 0 {
        assert!(std::time::Instant::now() < deadline, "scheduler never picked B up");
        std::thread::sleep(Duration::from_millis(1));
    }

    assert!(coord.drain(Duration::from_secs(60)), "drain must complete within the deadline");

    // B was parked (or at worst still queued): either way the drain
    // must retire it synchronously with the typed retryable error and
    // zero streamed tokens
    let ob = drain_session(&hb);
    assert_eq!(ob.terminals, 1, "the parked stream must see exactly one terminal event");
    assert!(ob.tokens.is_empty(), "a parked request must never stream tokens through a drain");
    let err = ob.error.expect("the parked request must retire with a typed error");
    assert_eq!(err, RequestError::Draining);
    assert!(err.retryable(), "Draining must be marked retryable (another replica may serve)");

    // the in-flight stream was untouched: one Done, all 200 tokens
    let oa = drain_session(&ha);
    assert_eq!(oa.terminals, 1);
    assert!(oa.error.is_none(), "drain must never error the in-flight stream: {:?}", oa.error);
    assert_eq!(oa.done.expect("A must finish").tokens.len(), 200);
    let m = coord.metrics.lock().unwrap();
    assert_eq!(m.requests_completed, 1);
    drop(m);
    drop(engine);
}

/// Replica failure isolation (DESIGN.md §14, the PR-9 acceptance gate):
/// on a 2-replica set, a panic in ONE replica (restart budget zero, so
/// it dies for good) fails only ITS in-flight stream — with a typed
/// retryable `EngineFailed` naming the replica — while its
/// queued-but-undispatched work transparently fails over to the
/// survivor and every completed stream is bit-identical to a fault-free
/// run.
#[test]
fn panic_in_one_replica_isolates_failure_and_fails_over_queued_work() {
    let mut rng = Rng::seed_from_u64(81);
    let prompt = generate(Task::PRe, &mut rng, 96).prompt;
    let req = || Request { prompt: prompt.clone(), max_new: 12, ignore_eos: true, ..Default::default() };

    // fault-free reference (greedy decode ⇒ every completion must match)
    let (clean, clean_engine) = start_coordinator(ServingConfig::default());
    let reference = clean.submit(req()).unwrap().tokens;
    common::assert_pool_drained(&clean_engine);

    // replica 0 is clean; replica 1 panics at backend call 30 — inside
    // its first request's decode (prefill ≈ 9 calls, each decode round
    // well over 1), long before a 12-token stream can finish
    let engine0 = EngineHandle::spawn_replica(artifacts(), 0).unwrap();
    let engine1 = EngineHandle::spawn_replica_with(
        artifacts(),
        None,
        Some(FaultPlan::new().with(30, FaultKind::Panic)),
        1,
    )
    .unwrap();
    let coord = Coordinator::start_replicas(
        vec![engine0.clone(), engine1.clone()],
        ServingConfig {
            // one active request per replica: the second request each
            // replica receives sits QUEUED, which is what failover moves
            max_active_requests: 1,
            // no respawns: replica 1's death is permanent, so its queued
            // work MUST fail over to survive
            engine_restart_max: 0,
            ..Default::default()
        },
    )
    .unwrap();

    // identical prompts ⇒ identical committed tokens ⇒ least-loaded
    // dispatch alternates deterministically: r0, r1, r0, r1
    let handles: Vec<SessionHandle> = (0..4).map(|_| coord.open(req()).unwrap()).collect();
    let outcomes: Vec<StreamOutcome> = handles.iter().map(drain_session).collect();

    let mut completed = 0;
    let mut failed = 0;
    for (i, o) in outcomes.iter().enumerate() {
        assert_eq!(o.terminals, 1, "session {i} must see exactly one terminal event");
        match (&o.done, &o.error) {
            (Some(done), None) => {
                completed += 1;
                assert_eq!(done.tokens, reference, "session {i}: completed stream diverged");
            }
            (None, Some(err)) => {
                failed += 1;
                match err {
                    RequestError::EngineFailed { replica, .. } => {
                        assert_eq!(*replica, 1, "only replica 1 may fail sessions");
                    }
                    other => panic!("session {i}: expected EngineFailed, got {other:?}"),
                }
                assert!(err.retryable(), "replica death must be retryable (peers serve)");
            }
            other => panic!("session {i}: inconsistent terminal state {other:?}"),
        }
    }
    // exactly the one stream in flight on replica 1 dies; its queued
    // request and both replica-0 streams complete
    assert_eq!(failed, 1, "replica 1's in-flight stream must be the only casualty");
    assert_eq!(completed, 3, "queued work must fail over to the survivor");

    let m = coord.metrics.lock().unwrap();
    assert!(m.dispatch_failovers >= 1, "the queued request must be counted as a failover");
    assert_eq!(m.replicas[1].deaths, 1, "replica 1 must be marked dead exactly once");
    assert_eq!(m.engine_restarts, 0);
    drop(m);
    // the survivor keeps serving the same stream bit-identically...
    let got = coord.submit(req()).unwrap();
    assert_eq!(got.tokens, reference);
    assert_eq!(got.replica, 0, "only replica 0 is left to serve");
    // ...and its pool drains to zero. (Replica 1's pool died with its
    // engine lifetime — with a zero restart budget there is no live
    // lifetime left to interrogate, same as the post-drain idiom.)
    common::assert_pool_drained(&engine0);
    assert_eq!(engine1.generation(), 0, "a zero restart budget must never respawn");
}

/// Satellite-1 regression (DESIGN.md §14): a respawned engine must not
/// serve — or retain — prefix pages indexed from the DEAD lifetime's
/// pool. After a mid-stream panic under an armed prefix cache, the
/// fresh lifetime starts cold (the same prompt MISSES, then re-warms),
/// streams stay bit-identical, and `drained_with_retained` holds across
/// the restart (the pool fully drains net of legitimately retained
/// pages).
#[test]
fn respawn_clears_prefix_index_and_drains_with_retention() {
    let mut rng = Rng::seed_from_u64(82);
    let prompt = generate(Task::PRe, &mut rng, 96).prompt;
    let req = |max_new: usize| Request {
        prompt: prompt.clone(),
        max_new,
        ignore_eos: true,
        ..Default::default()
    };

    let (clean, clean_engine) = start_coordinator(ServingConfig::default());
    let reference = clean.submit(req(4)).unwrap().tokens;
    common::assert_pool_drained(&clean_engine);

    // call 150 lands inside request B's decode: request A (cold 96-token
    // prefill + 4 decode rounds) stays well under it, B (16 rounds)
    // reaches well past it
    let plan = FaultPlan::new().with(150, FaultKind::Panic);
    let engine = EngineHandle::spawn_with_faults(artifacts(), None, plan).unwrap();
    let coord = Coordinator::start(
        engine.clone(),
        ServingConfig {
            prefix_cache: true,
            engine_restart_backoff_ms: 10,
            ..Default::default()
        },
    )
    .unwrap();

    // A: cold — warms the prefix cache and retains its prompt pages
    let a = coord.submit(req(4)).unwrap();
    assert_eq!(a.tokens, reference, "cold prefix-cached stream must match the clean run");
    common::assert_pool_drained(&engine); // drained_with_retained: retained pages are legitimate

    // B: warm hit on the same prompt, then the injected panic kills the
    // lifetime mid-decode — typed, retryable
    let hb = coord.open(req(16)).unwrap();
    let ob = drain_session(&hb);
    assert_eq!(ob.terminals, 1);
    let err = ob.error.expect("the panic must fail the in-flight warm stream");
    assert!(matches!(err, RequestError::EngineFailed { .. }), "{err:?}");

    // C: the respawned lifetime must start COLD — a stale index pointing
    // at the dead pool's pages would either corrupt C or retain ghost
    // pages. C re-warms the cache; D then hits it again.
    let c = coord.submit(req(4)).unwrap();
    assert_eq!(c.tokens, reference, "post-restart stream must be bit-identical");
    let d = coord.submit(req(4)).unwrap();
    assert_eq!(d.tokens, reference);

    let m = coord.metrics.lock().unwrap();
    assert!(m.engine_restarts >= 1, "supervision must have respawned the engine");
    assert!(
        m.prefix_misses >= 2,
        "A (cold) and C (post-restart, cleared index) must both miss: {}",
        m.summary()
    );
    assert!(m.prefix_hits >= 2, "B and D must hit the warm cache: {}", m.summary());
    drop(m);
    assert!(engine.generation() >= 1);
    // the regression's core assert: the fresh lifetime's pool drains to
    // zero net of ITS OWN retained prefix pages — nothing carried over
    // from the dead pool's index
    common::assert_pool_drained(&engine);
    let stats = engine.prefix_stats().unwrap();
    assert!(
        stats.retained_pages > 0,
        "C/D must have re-warmed the fresh lifetime's cache: {stats:?}"
    );
}

/// Seeded chaos over a TWO-replica set (the CI sweep target): each
/// replica's first lifetime draws its own fault schedule from
/// `FLUX_FAULT_SEED`, and whatever mix of errs, panics and stalls they
/// land, every session terminates exactly once (typed), the set
/// recovers, and BOTH pools drain.
#[test]
fn seeded_faults_on_a_two_replica_set_terminate_and_recover() {
    let base: u64 = std::env::var("FLUX_FAULT_SEED")
        .ok()
        .and_then(|v| v.trim().parse().ok())
        .unwrap_or(1);
    for seed in base..base + 4 {
        let engines: Vec<EngineHandle> = (0..2)
            .map(|i| {
                EngineHandle::spawn_replica_with(
                    artifacts(),
                    None,
                    Some(FaultPlan::seeded(seed.wrapping_add(i as u64 * 1000))),
                    i,
                )
                .unwrap()
            })
            .collect();
        let coord = Coordinator::start_replicas(
            engines.clone(),
            ServingConfig {
                engine_round_timeout_ms: Some(30_000),
                engine_restart_max: 4,
                engine_restart_backoff_ms: 10,
                ..Default::default()
            },
        )
        .unwrap();
        let mut rng = Rng::seed_from_u64(seed);
        let reqs: Vec<Request> = (0..4)
            .map(|_| {
                let len = 64 + rng.gen_range(64);
                let max_new = 6 + rng.gen_range(8);
                Request {
                    prompt: generate(Task::PRe, &mut rng, len).prompt,
                    max_new,
                    ignore_eos: true,
                    ..Default::default()
                }
            })
            .collect();
        let handles: Vec<SessionHandle> =
            reqs.iter().map(|r| coord.open(r.clone()).unwrap()).collect();
        for (i, h) in handles.iter().enumerate() {
            let o = drain_session(h);
            assert_eq!(
                o.terminals, 1,
                "seed {seed}: session {i} must see exactly one terminal event"
            );
            if let Some(err) = &o.error {
                assert!(
                    matches!(err, RequestError::Engine(_) | RequestError::EngineFailed { .. }),
                    "seed {seed}: session {i} got a mistyped terminal {err:?}"
                );
            } else if let Some(done) = &o.done {
                assert_eq!(done.tokens.len(), reqs[i].max_new, "seed {seed}: max_new violated");
                assert!(done.replica < 2, "seed {seed}: impossible replica id");
            }
        }
        // recovery liveness: with per-replica restart budgets of 4 and
        // at most one lifetime-killing fault per plan, SOME replica is
        // serving — a probe completes within a few typed retries
        let probe = Request {
            prompt: generate(Task::Gov, &mut rng, 48).prompt,
            max_new: 4,
            ignore_eos: true,
            ..Default::default()
        };
        let mut served = false;
        for _ in 0..5 {
            let h = coord
                .open(probe.clone())
                .unwrap_or_else(|e| panic!("seed {seed}: probe admission failed: {e:?}"));
            let o = drain_session(&h);
            assert_eq!(o.terminals, 1, "seed {seed}: probe must terminate exactly once");
            if o.done.is_some() {
                served = true;
                break;
            }
        }
        assert!(served, "seed {seed}: the replica set did not recover");
        for e in &engines {
            common::assert_pool_drained(e);
        }
    }
}

/// With the restart budget exhausted (`engine_restart_max: 0`), a dead
/// engine fails everything typed and the scheduler shuts down — no
/// restart, no hang, and later submissions still get a typed error.
#[test]
fn exhausted_restart_budget_fails_typed_and_shuts_down() {
    let mut rng = Rng::seed_from_u64(75);
    let prompt = generate(Task::Gov, &mut rng, 64).prompt;
    let req = |max_new: usize| Request {
        prompt: prompt.clone(),
        max_new,
        ignore_eos: true,
        ..Default::default()
    };

    let plan = FaultPlan::new().with(30, FaultKind::Panic);
    let engine = EngineHandle::spawn_with_faults(artifacts(), None, plan).unwrap();
    let coord = Coordinator::start(
        engine.clone(),
        ServingConfig { engine_restart_max: 0, ..Default::default() },
    )
    .unwrap();

    let h = coord.open(req(16)).unwrap();
    let o = drain_session(&h);
    assert_eq!(o.terminals, 1, "the victim must see exactly one terminal event");
    let err = o.error.expect("the victim must retire with a typed error");
    assert!(
        matches!(err, RequestError::EngineFailed { .. }),
        "engine death must surface as EngineFailed, got {err:?}"
    );

    // no restart happened — the budget was zero
    assert_eq!(coord.metrics.lock().unwrap().engine_restarts, 0);
    assert_eq!(engine.generation(), 0, "an exhausted budget must never respawn the engine");

    // the scheduler has wound down (the drain handshake resolves
    // immediately against the done flag its exit guard set), and later
    // submissions are fenced synchronously with a typed error instead
    // of hanging: the admission fence rejects first; a racing enqueue
    // that slips past it hits the disconnected queue as `Shutdown`
    assert!(coord.drain(Duration::from_secs(10)), "a dead scheduler must report done");
    let late = coord.open(req(4)).expect_err("no request may be admitted after shutdown");
    assert!(
        matches!(late, RequestError::Draining | RequestError::Shutdown),
        "late submission must fail typed, got {late:?}"
    );
}
