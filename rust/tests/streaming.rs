//! Integration tests for the event-driven session API and the
//! multiplexed wire protocol v2: streamed-vs-blocking determinism,
//! mid-stream cancellation (engine-slot reclamation), deadlines, stop
//! tokens, admission validation, and server robustness against
//! malformed input on live connections.
//!
//! Artifacts resolution mirrors `integration.rs`: `$FLUX_ARTIFACTS`
//! when populated, otherwise hermetic synthetic artifacts — every test
//! executes on every `cargo test`.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use flux_attention::config::{MetaConfig, ServingConfig};
use flux_attention::coordinator::{Coordinator, Request, RequestError, SessionEvent};
use flux_attention::engine::EngineHandle;
use flux_attention::router::{AttnMode, DecodeMode, Policy};
use flux_attention::runtime::synthetic;
use flux_attention::server::{serve_listener, StreamClient, WireRequest};
use flux_attention::util::bench::{run_streaming_bench, ServingBenchOpts};
use flux_attention::util::json::Json;
use flux_attention::util::rng::Rng;
use flux_attention::workload::{generate, Task};

mod common;

const TIMEOUT: Duration = Duration::from_secs(120);

fn artifacts() -> PathBuf {
    synthetic::ensure_default().expect("artifact generation must not fail")
}

fn start_coordinator(cfg: ServingConfig) -> (Arc<Coordinator>, EngineHandle) {
    let engine = EngineHandle::spawn(artifacts()).unwrap();
    let coord = Coordinator::start(engine.clone(), cfg).unwrap();
    (coord, engine)
}

/// Coordinator + TCP server on an ephemeral port.
fn start_server() -> (Arc<Coordinator>, String, EngineHandle) {
    let dir = artifacts();
    let n_layers = MetaConfig::load(&dir).unwrap().model.n_layers;
    let engine = EngineHandle::spawn(dir).unwrap();
    let coord = Coordinator::start(engine.clone(), ServingConfig::default()).unwrap();
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let serve_coord = coord.clone();
    std::thread::spawn(move || {
        let _ = serve_listener(serve_coord, listener, n_layers);
    });
    (coord, addr, engine)
}

/// Acceptance gate: the streamed token sequence (Prefilled.first_token
/// then Token events) must equal both the Done stats and the blocking
/// API's tokens for the same prompt — greedy determinism is preserved
/// across the event-driven redesign.
#[test]
fn streamed_tokens_match_blocking_api() {
    let (coord, engine) = start_coordinator(ServingConfig::default());
    let mut rng = Rng::seed_from_u64(31);
    let s = generate(Task::PRe, &mut rng, 200);
    let req = Request {
        prompt: s.prompt.clone(),
        max_new: 6,
        policy: Policy::Flux { sa_mode: AttnMode::Ssa, decode: DecodeMode::Dense },
        ..Default::default()
    };
    let blocking = coord.submit(req.clone()).unwrap();

    let handle = coord.open(req).unwrap();
    let mut streamed = vec![];
    let mut saw_queued = false;
    let mut saw_prefilled = false;
    let mut stats = None;
    while let Some(ev) = handle.recv_timeout(TIMEOUT) {
        match ev {
            SessionEvent::Queued => saw_queued = true,
            SessionEvent::Prefilled { first_token, ttft_us, .. } => {
                saw_prefilled = true;
                assert!(ttft_us > 0);
                streamed.push(first_token);
            }
            SessionEvent::Token { tok, .. } => streamed.push(tok),
            SessionEvent::Done { stats: st } => {
                stats = Some(st);
                break;
            }
            SessionEvent::Error { error } => panic!("unexpected error: {error}"),
            // uncontended pool: preemption never fires here
            SessionEvent::Preempted { .. } | SessionEvent::Resumed { .. } => {}
        }
    }
    assert!(saw_queued, "Queued must precede everything");
    assert!(saw_prefilled, "Prefilled must be emitted");
    let stats = stats.expect("stream must end with Done");
    assert_eq!(streamed, stats.tokens, "event stream must mirror the final token list");
    assert_eq!(streamed, blocking.tokens, "streaming must preserve greedy determinism");
    assert!(stats.e2e_us >= stats.ttft_us);
    common::assert_pool_drained(&engine);
}

/// Acceptance gate: cancelling a mid-stream session frees its engine
/// slot — with `max_active_requests == 1`, a second request queued
/// behind the victim admits and completes only after the cancel.
#[test]
fn mid_stream_cancel_frees_engine_slot() {
    let (coord, engine) =
        start_coordinator(ServingConfig { max_active_requests: 1, ..Default::default() });
    let mut rng = Rng::seed_from_u64(32);
    let sa = generate(Task::PRe, &mut rng, 128);
    let sb = generate(Task::Gov, &mut rng, 128);

    // A occupies the single slot with a long, EOS-proof generation
    let ha = coord
        .open(Request { prompt: sa.prompt, max_new: 1024, ignore_eos: true, ..Default::default() })
        .unwrap();
    let mut tokens_before_cancel = 0;
    while tokens_before_cancel < 3 {
        match ha.recv_timeout(TIMEOUT) {
            Some(SessionEvent::Token { .. }) => tokens_before_cancel += 1,
            Some(SessionEvent::Error { error }) => panic!("A errored early: {error}"),
            Some(_) => {}
            None => panic!("A's stream closed early"),
        }
    }

    // B queues behind the occupied slot, then A is cancelled
    let hb = coord
        .open(Request { prompt: sb.prompt, max_new: 3, ignore_eos: true, ..Default::default() })
        .unwrap();
    ha.cancel();
    let err = loop {
        match ha.recv_timeout(TIMEOUT) {
            Some(SessionEvent::Error { error }) => break error,
            Some(SessionEvent::Done { .. }) => panic!("cancelled session must not complete"),
            Some(_) => {}
            None => panic!("A's stream closed without a terminal event"),
        }
    };
    assert_eq!(err, RequestError::Cancelled);

    // the freed slot admits B, which runs to completion
    let resp = hb.wait().unwrap();
    assert_eq!(resp.tokens.len(), 3);
    let m = coord.metrics.lock().unwrap();
    assert_eq!(m.requests_cancelled, 1);
    assert_eq!(m.requests_completed, 1);
    assert!(m.stream_tokens.count() >= 2, "both sessions record streamed tokens");
    drop(m);
    common::assert_pool_drained(&engine);
}

#[test]
fn deadline_exceeded_evicts_between_steps() {
    let (coord, engine) = start_coordinator(ServingConfig::default());
    let mut rng = Rng::seed_from_u64(33);
    // a 1024-token prompt makes prefill alone outlast a 5ms deadline,
    // so expiry is deterministic on any machine; max_new stays inside
    // the 2048-slot KV ledger so the only possible terminal error is
    // the deadline
    let s = generate(Task::PRe, &mut rng, 1024);
    let h = coord
        .open(Request {
            prompt: s.prompt.clone(),
            max_new: 500,
            ignore_eos: true,
            deadline_ms: Some(5),
            ..Default::default()
        })
        .unwrap();
    let err = loop {
        match h.recv_timeout(TIMEOUT) {
            Some(SessionEvent::Error { error }) => break error,
            Some(SessionEvent::Done { .. }) => panic!("must expire before completing"),
            Some(_) => {}
            None => panic!("stream closed without a terminal event"),
        }
    };
    assert_eq!(err, RequestError::DeadlineExceeded);
    assert_eq!(coord.metrics.lock().unwrap().requests_expired, 1);

    // the slot was reclaimed: a follow-up request completes
    let resp = coord
        .submit(Request { prompt: s.prompt, max_new: 2, ignore_eos: true, ..Default::default() })
        .unwrap();
    assert_eq!(resp.tokens.len(), 2);

    // config-level default deadline applies when the request has none
    let (coord2, engine2) = start_coordinator(ServingConfig {
        default_deadline_ms: Some(5),
        ..Default::default()
    });
    let mut rng2 = Rng::seed_from_u64(34);
    let s2 = generate(Task::Gov, &mut rng2, 1024);
    let err2 = coord2
        .submit(Request { prompt: s2.prompt, max_new: 500, ignore_eos: true, ..Default::default() })
        .unwrap_err();
    assert!(
        err2.to_string().contains("deadline exceeded"),
        "default deadline must evict: {err2}"
    );
    common::assert_pool_drained(&engine);
    common::assert_pool_drained(&engine2);
}

#[test]
fn stop_tokens_terminate_generation() {
    let (coord, engine) = start_coordinator(ServingConfig::default());
    let mut rng = Rng::seed_from_u64(35);
    let s = generate(Task::PRe, &mut rng, 128);
    let base = coord
        .submit(Request { prompt: s.prompt.clone(), max_new: 8, ignore_eos: true, ..Default::default() })
        .unwrap();
    assert_eq!(base.tokens.len(), 8, "ignore_eos must decode to max_new");

    // stopping on the value of the third token truncates at its first
    // occurrence (inclusive), wherever that is
    let stop = base.tokens[2];
    let first_idx = base.tokens.iter().position(|&t| t == stop).unwrap();
    let resp = coord
        .submit(Request {
            prompt: s.prompt.clone(),
            max_new: 8,
            ignore_eos: true,
            stop_tokens: vec![stop],
            ..Default::default()
        })
        .unwrap();
    assert_eq!(
        resp.tokens,
        base.tokens[..=first_idx].to_vec(),
        "generation must stop at the stop token (inclusive)"
    );
    common::assert_pool_drained(&engine);
}

#[test]
fn admission_rejects_invalid_requests_with_typed_errors() {
    let dir = artifacts();
    let max = *MetaConfig::load(&dir).unwrap().prefill_buckets.last().unwrap();
    let (coord, engine) = start_coordinator(ServingConfig::default());

    // over-long prompt: typed coordinator error, not an engine failure
    match coord.open(Request { prompt: vec![7; max + 1], ..Default::default() }) {
        Err(RequestError::PromptTooLong { len, max: m }) => {
            assert_eq!(len, max + 1);
            assert_eq!(m, max);
        }
        Err(e) => panic!("wrong error: {e:?}"),
        Ok(_) => panic!("oversized prompt must be rejected"),
    }
    // empty prompt
    assert!(matches!(
        coord.open(Request { prompt: vec![], ..Default::default() }),
        Err(RequestError::Invalid(_))
    ));
    // oversized max_new
    assert!(matches!(
        coord.open(Request { prompt: vec![1], max_new: 1_000_000, ..Default::default() }),
        Err(RequestError::Invalid(_))
    ));
    // all three were counted as rejections and never reached the engine
    assert_eq!(coord.metrics.lock().unwrap().requests_rejected, 3);
    assert_eq!(coord.metrics.lock().unwrap().requests_completed, 0);
    common::assert_pool_drained(&engine);
}

fn send_recv(wr: &mut TcpStream, rd: &mut BufReader<TcpStream>, msg: &str) -> Json {
    wr.write_all(msg.as_bytes()).unwrap();
    wr.write_all(b"\n").unwrap();
    wr.flush().unwrap();
    let mut line = String::new();
    rd.read_line(&mut line).unwrap();
    assert!(!line.is_empty(), "server must answer, not close (sent: {msg})");
    Json::parse(&line).unwrap()
}

/// Satellite: every malformed input is answered with an error on the
/// same connection, and the connection keeps serving afterwards.
#[test]
fn server_survives_malformed_inputs() {
    let (_coord, addr, engine) = start_server();
    let sock = TcpStream::connect(&addr).unwrap();
    let mut wr = sock.try_clone().unwrap();
    let mut rd = BufReader::new(sock);

    // bad JSON
    let r = send_recv(&mut wr, &mut rd, "this is not json");
    assert!(r.get("error").and_then(Json::as_str).unwrap().contains("bad json"));
    // unknown policy
    let r = send_recv(&mut wr, &mut rd, r#"{"prompt":[1,2],"policy":"nope"}"#);
    assert!(r.get("error").and_then(Json::as_str).unwrap().contains("unknown policy"));
    // empty prompt
    let r = send_recv(&mut wr, &mut rd, r#"{"prompt":[]}"#);
    assert!(r.get("error").and_then(Json::as_str).unwrap().contains("empty prompt"));
    // oversized max_new
    let r = send_recv(&mut wr, &mut rd, r#"{"prompt":[1],"max_new":100000000}"#);
    assert!(r.get("error").and_then(Json::as_str).unwrap().contains("max_new"));
    // over-long prompt: clean typed admission error
    let big: Vec<String> = (0..5000).map(|_| "7".to_string()).collect();
    let r = send_recv(&mut wr, &mut rd, &format!(r#"{{"prompt":[{}]}}"#, big.join(",")));
    assert!(r.get("error").and_then(Json::as_str).unwrap().contains("prefill bucket"));
    // v2 cancel for an unknown id
    let r = send_recv(&mut wr, &mut rd, r#"{"id":5,"cancel":true}"#);
    assert_eq!(r.get("kind").and_then(Json::as_str), Some("unknown_id"));
    // v2 open with a bad policy: error frame carrying the id
    let r = send_recv(&mut wr, &mut rd, r#"{"id":6,"prompt":[1],"policy":"zzz"}"#);
    assert_eq!(r.get("id").and_then(Json::as_usize), Some(6));
    assert_eq!(r.get("kind").and_then(Json::as_str), Some("invalid"));

    // after all that, a valid v1 request still round-trips — with
    // queue_ms now on the wire
    let r = send_recv(&mut wr, &mut rd, r#"{"prompt":[1,2,3],"max_new":2,"policy":"backbone"}"#);
    assert!(r.get("error").is_some_and(|e| e == &Json::Null), "unexpected error: {r}");
    assert!(!r.get("tokens").and_then(Json::as_arr).unwrap().is_empty());
    assert!(r.get("queue_ms").and_then(Json::as_f64).is_some(), "queue_ms must be on the wire");
    common::assert_pool_drained(&engine);
}

/// Satellite: one connection carries a v2 stream and a v1 single-shot
/// request at the same time; both complete, and the v2 event stream's
/// token order matches its own done frame.
#[test]
fn mixed_v1_v2_connection_roundtrip() {
    let (_coord, addr, engine) = start_server();
    let mut rng = Rng::seed_from_u64(36);
    let sa = generate(Task::PRe, &mut rng, 100);
    let sb = generate(Task::Gov, &mut rng, 100);

    let sock = TcpStream::connect(&addr).unwrap();
    let mut wr = sock.try_clone().unwrap();
    let mut rd = BufReader::new(sock);

    let v2 = WireRequest {
        prompt: sa.prompt.clone(),
        max_new: 4,
        policy: "backbone".into(),
        id: Some(1),
        ignore_eos: true,
        ..Default::default()
    };
    wr.write_all(format!("{}\n", v2.to_json()).as_bytes()).unwrap();
    let v1 = WireRequest {
        prompt: sb.prompt.clone(),
        max_new: 3,
        policy: "backbone".into(),
        ignore_eos: true,
        ..Default::default()
    };
    wr.write_all(format!("{}\n", v1.to_json()).as_bytes()).unwrap();
    wr.flush().unwrap();

    let mut v1_resp = None;
    let mut v2_done = None;
    let mut v2_streamed: Vec<u32> = vec![];
    for _ in 0..200 {
        if v1_resp.is_some() && v2_done.is_some() {
            break;
        }
        let mut line = String::new();
        rd.read_line(&mut line).unwrap();
        assert!(!line.is_empty(), "connection closed mid-conversation");
        let j = Json::parse(&line).unwrap();
        match j.get("id").and_then(Json::as_usize) {
            None => v1_resp = Some(j),
            Some(1) => match j.get("event").and_then(Json::as_str) {
                Some("prefilled") | Some("token") => {
                    v2_streamed.push(j.get("token").and_then(Json::as_usize).unwrap() as u32);
                }
                Some("done") => v2_done = Some(j),
                Some("error") => panic!("v2 stream failed: {j}"),
                _ => {}
            },
            Some(other) => panic!("unexpected stream id {other}"),
        }
    }
    let v1_resp = v1_resp.expect("v1 response must arrive");
    assert!(v1_resp.get("error").is_some_and(|e| e == &Json::Null), "{v1_resp}");
    assert_eq!(v1_resp.get("tokens").and_then(Json::as_arr).unwrap().len(), 3);

    let v2_done = v2_done.expect("v2 done frame must arrive");
    let done_tokens: Vec<u32> = v2_done
        .get("tokens")
        .and_then(Json::as_arr)
        .unwrap()
        .iter()
        .filter_map(|v| v.as_usize().map(|x| x as u32))
        .collect();
    assert_eq!(done_tokens.len(), 4);
    assert_eq!(v2_streamed, done_tokens, "frame order must equal the final sequence");
    common::assert_pool_drained(&engine);
}

/// Wire-level cancellation through the multiplexing client: the victim
/// gets a terminal `cancelled` error frame, a sibling stream on the
/// same connection is unaffected, and the coordinator counts the
/// reclaim.
#[test]
fn wire_cancel_aborts_stream_and_frees_slot() {
    let (coord, addr, engine) = start_server();
    let mut rng = Rng::seed_from_u64(37);
    let sv = generate(Task::PRe, &mut rng, 100);
    let ss = generate(Task::Gov, &mut rng, 100);

    let client = StreamClient::connect(&addr).unwrap();
    let victim = client
        .open(&WireRequest { prompt: sv.prompt, max_new: 1024, ignore_eos: true, ..Default::default() })
        .unwrap();
    // wait until the victim is streaming tokens
    loop {
        let j = victim.recv_timeout(TIMEOUT).expect("victim stream must produce frames");
        if j.get("event").and_then(Json::as_str) == Some("token") {
            break;
        }
    }
    victim.cancel().unwrap();
    let mut saw_cancelled = false;
    while let Some(j) = victim.recv_timeout(TIMEOUT) {
        if j.get("event").and_then(Json::as_str) == Some("error") {
            assert_eq!(j.get("kind").and_then(Json::as_str), Some("cancelled"));
            saw_cancelled = true;
            break;
        }
    }
    assert!(saw_cancelled, "victim must receive a terminal cancelled frame");

    // sibling stream on the same connection completes normally
    let sibling = client
        .open(&WireRequest { prompt: ss.prompt, max_new: 3, ignore_eos: true, ..Default::default() })
        .unwrap();
    let resp = sibling.wait().unwrap();
    assert!(resp.error.is_none(), "{:?}", resp.error);
    assert_eq!(resp.tokens.len(), 3);

    let m = coord.metrics.lock().unwrap();
    assert!(m.requests_cancelled >= 1, "coordinator must count the wire cancel");
    assert!(m.requests_completed >= 1);
    drop(m);
    common::assert_pool_drained(&engine);
}

/// Slow-client backpressure (DESIGN.md §15 hardening): a connection
/// that opens a long stream and then never reads a byte must not stall
/// a sibling connection — every connection's outbound frames flow
/// through its own bounded queue, so only the slow connection's pumps
/// ever block. Once the slow client goes away, its stream (and ONLY
/// its stream) is cancelled and the engine reclaims slot + KV pages.
#[test]
fn never_reading_client_does_not_stall_sibling_stream() {
    let (coord, addr, engine) = start_server();
    let mut rng = Rng::seed_from_u64(39);
    let slow_prompt = generate(Task::PRe, &mut rng, 100).prompt;
    let sib_prompt = generate(Task::Gov, &mut rng, 100).prompt;

    // the slow connection: open a long stream, then never read — the
    // server's frames pile into its bounded outbound queue
    let slow = TcpStream::connect(&addr).unwrap();
    let mut wr = slow.try_clone().unwrap();
    let req = WireRequest {
        prompt: slow_prompt,
        max_new: 2048,
        policy: "backbone".into(),
        id: Some(1),
        ignore_eos: true,
        ..Default::default()
    };
    wr.write_all(format!("{}\n", req.to_json()).as_bytes()).unwrap();
    wr.flush().unwrap();
    // wait until the slow stream is genuinely decoding server-side
    let deadline = std::time::Instant::now() + TIMEOUT;
    while coord.metrics.lock().unwrap().decode_rounds == 0 {
        assert!(std::time::Instant::now() < deadline, "slow stream never started decoding");
        std::thread::sleep(Duration::from_millis(2));
    }

    // the sibling connection must stream to completion while the slow
    // client sits on an ever-growing backlog
    let client = StreamClient::connect(&addr).unwrap();
    let sibling = client
        .open(&WireRequest { prompt: sib_prompt, max_new: 8, ignore_eos: true, ..Default::default() })
        .unwrap();
    let resp = sibling.wait().unwrap();
    assert!(resp.error.is_none(), "sibling stream must not error: {:?}", resp.error);
    assert_eq!(resp.tokens.len(), 8, "sibling stream must finish all its tokens");
    // exactly the sibling completed — the 2048-token slow stream cannot
    // have outrun an 8-token one
    assert_eq!(coord.metrics.lock().unwrap().requests_completed, 1);

    // the slow client disappears: the server must cancel ITS stream
    // (typed, counted) and reclaim the pages — nothing else
    let _ = slow.shutdown(std::net::Shutdown::Both);
    drop(slow);
    let deadline = std::time::Instant::now() + TIMEOUT;
    while coord.metrics.lock().unwrap().requests_cancelled == 0 {
        assert!(
            std::time::Instant::now() < deadline,
            "the dead connection's stream was never cancelled"
        );
        std::thread::sleep(Duration::from_millis(5));
    }
    let m = coord.metrics.lock().unwrap();
    assert_eq!(m.requests_cancelled, 1, "only the slow connection's own stream is cancelled");
    assert_eq!(m.requests_completed, 1, "the sibling's completion stands");
    drop(m);
    common::assert_pool_drained(&engine);
}

/// The streaming serving bench (the CI smoke gate's third artifact)
/// writes valid JSON with cleanup proof.
#[test]
fn streaming_bench_smoke_writes_valid_json() {
    let dir = artifacts();
    let out = std::env::temp_dir().join(format!("flux-stream-bench-{}", std::process::id()));
    std::fs::create_dir_all(&out).unwrap();
    let opts = ServingBenchOpts {
        seq_len: 96,
        decode_tokens: 4,
        threads: 2,
        out_dir: out.clone(),
        smoke: true,
    };
    let p = run_streaming_bench(&dir, &opts).unwrap();
    let j = Json::parse(&std::fs::read_to_string(&p).unwrap()).unwrap();
    assert_eq!(j.get("schema").and_then(Json::as_str), Some("flux-bench-serving/v6"));
    assert_eq!(j.get("measured").and_then(Json::as_bool), Some(true));
    assert_eq!(j.get("cancelled_cleanup_ok").and_then(Json::as_bool), Some(true));
    assert!(j.get("tokens_per_s").and_then(Json::as_f64).unwrap() > 0.0);
    assert!(j.get("cancelled_requests").and_then(Json::as_f64).unwrap() >= 1.0);
    assert!(j.get("metrics_summary").and_then(Json::as_str).unwrap().contains("cancelled="));
    // the pool-pressure scenario (DESIGN.md §11) must be measured: page
    // occupancy visible, a typed overloaded rejection recorded, and the
    // page-size sweep verified bit-identical
    let pp = j.get("pool_pressure").expect("pool_pressure scenario missing");
    assert!(pp.get("pages_peak").and_then(Json::as_f64).unwrap() > 0.0);
    assert!(pp.get("overloaded_rejections").and_then(Json::as_f64).unwrap() >= 1.0);
    assert_eq!(pp.get("bit_identical").and_then(Json::as_bool), Some(true));
    assert!(j.get("metrics_summary").and_then(Json::as_str).unwrap().contains("pages="));
    // the fault-recovery scenario (DESIGN.md §12) must be measured: a
    // supervised restart happened and the post-restart stream matched
    // the pre-fault reference
    let fr = j.get("fault_recovery").expect("fault_recovery scenario missing");
    assert_eq!(fr.get("recovered").and_then(Json::as_bool), Some(true));
    assert!(fr.get("engine_restarts").and_then(Json::as_f64).unwrap() >= 1.0);
    assert_eq!(fr.get("bit_identical").and_then(Json::as_bool), Some(true));
    assert!(fr.get("time_to_readmit_ms").and_then(Json::as_f64).unwrap() >= 0.0);
    // the preemption scenario (DESIGN.md §15) must be measured: an
    // undersized pool under optimistic admission actually preempted AND
    // resumed, every stream completed, and the resumed streams matched
    // the worst-case serial reference bit for bit
    let pe = j.get("preemption").expect("preemption scenario missing");
    assert!(pe.get("preemptions").and_then(Json::as_f64).unwrap() >= 1.0);
    assert!(pe.get("resumes").and_then(Json::as_f64).unwrap() >= 1.0);
    assert!(pe.get("preempted_pages_freed").and_then(Json::as_f64).unwrap() >= 1.0);
    assert_eq!(pe.get("all_streams_completed").and_then(Json::as_bool), Some(true));
    assert_eq!(pe.get("bit_identical").and_then(Json::as_bool), Some(true));
    assert!(pe.get("goodput_optimistic_tokens_per_s").and_then(Json::as_f64).unwrap() > 0.0);
    assert!(pe.get("goodput_worst_case_tokens_per_s").and_then(Json::as_f64).unwrap() > 0.0);
    let _ = std::fs::remove_dir_all(&out);
}
