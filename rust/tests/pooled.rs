//! Paged KV pool acceptance tests (DESIGN.md §11): token streams must
//! be bit-identical across page sizes and decode paths (the pool's
//! page geometry is invisible to the math), retirement must return
//! every page to the pool, token-budget admission must park or reject
//! with typed errors instead of panicking or stalling, and the
//! request-lifecycle fixes of this PR (no `Prefilled` after a cancel
//! or an elapsed deadline, `max_new == 0` rejected at enqueue) are
//! pinned here.
//!
//! Artifacts resolution mirrors `integration.rs`: hermetic synthetic
//! artifacts — every test executes on every `cargo test`.

use std::path::PathBuf;
use std::time::Duration;

use flux_attention::config::ServingConfig;
use flux_attention::coordinator::{Coordinator, Request, RequestError, SessionEvent};
use flux_attention::engine::{Engine, EngineHandle};
use flux_attention::router::{AttnMode, DecodeMode, Policy};
use flux_attention::runtime::synthetic;
use flux_attention::util::prop::check;
use flux_attention::util::rng::Rng;
use flux_attention::workload::{generate, Task};
use flux_attention::{prop_assert, prop_assert_eq};

mod common;

const TIMEOUT: Duration = Duration::from_secs(120);

fn artifacts() -> PathBuf {
    synthetic::ensure_default().expect("artifact generation must not fail")
}

/// Coordinator plus a clone of its engine handle, so tests can assert
/// the KV pool drained after the traffic they drove.
fn start_coordinator(cfg: ServingConfig) -> (std::sync::Arc<Coordinator>, EngineHandle) {
    let engine = EngineHandle::spawn(artifacts()).unwrap();
    let coord = Coordinator::start(engine.clone(), cfg).unwrap();
    (coord, engine)
}

/// The tentpole safety net: for random mixed-mode batches (per-request
/// per-layer FA/SA routing, prompt lengths straddling the 128 prefill
/// bucket), batched decode on 16- and 64-token page pools must produce
/// token streams bit-identical to independent serial `decode_step`
/// loops on the default pool. 40 rounds push short prompts across the
/// 128 -> 256 FA growth edge (a copy + free + realloc inside the pool)
/// while sparse rings wrap, and a mid-round retirement frees one
/// request's pages for batchmates to recycle — the edges where paging
/// would corrupt state first. Every page must be back in the pool once
/// the batch drains.
#[test]
fn paged_pool_streams_bit_identical_across_page_sizes_and_paths() {
    let dir = artifacts();
    let mut reference = Engine::load(&dir).unwrap();
    let budget_tokens = 1 << 20; // generous: the pool arena grows lazily
    let mut engines: Vec<Engine> = [16usize, 64]
        .iter()
        .map(|&pt| Engine::load_with_pool(&dir, Some((pt, budget_tokens))).unwrap())
        .collect();
    let n_layers = reference.cfg().model.n_layers;
    let tasks = [Task::PRe, Task::Gov, Task::Qasper, Task::Trec];
    check("paged_pool_bit_identity", 3, |rng| {
        let b = 3usize;
        let steps = 40;
        let retire_at = steps / 2;
        let mut prompts = Vec::with_capacity(b);
        let mut policies = Vec::with_capacity(b);
        for _ in 0..b {
            let len = rng.range(100, 200);
            let task = tasks[rng.gen_range(tasks.len())];
            prompts.push(generate(task, rng, len).prompt);
            let modes: Vec<AttnMode> = (0..n_layers)
                .map(|_| if rng.f64() < 0.5 { AttnMode::Fa } else { AttnMode::Ssa })
                .collect();
            policies.push(Policy::Static { modes, decode: DecodeMode::Sparse });
        }

        // reference: independent serial decode loops, default pool
        let mut want: Vec<Vec<u32>> = Vec::with_capacity(b);
        for (prompt, policy) in prompts.iter().zip(&policies) {
            let (id, report) =
                reference.prefill(prompt, policy, "balanced").map_err(|e| e.to_string())?;
            let mut toks = vec![report.first_token];
            for _ in 0..steps {
                toks.push(reference.decode_step(id).map_err(|e| e.to_string())?);
            }
            reference.release(id);
            want.push(toks);
        }

        for e in engines.iter_mut() {
            let mut ids = Vec::with_capacity(b);
            let mut order: Vec<usize> = (0..b).collect();
            let mut got: Vec<Vec<u32>> = vec![Vec::new(); b];
            for (slot, (prompt, policy)) in prompts.iter().zip(&policies).enumerate() {
                let (id, report) =
                    e.prefill(prompt, policy, "balanced").map_err(|e| e.to_string())?;
                ids.push(id);
                got[slot].push(report.first_token);
            }
            for round in 0..steps {
                if round == retire_at {
                    // mid-round retirement: slot 1's pages return to the
                    // pool; survivors' growth may recycle them
                    e.release(ids.remove(1));
                    order.remove(1);
                }
                for (slot, tok) in order.iter().zip(e.decode_batch(&ids)) {
                    got[*slot].push(tok.map_err(|e| e.to_string())?);
                }
            }
            for id in ids {
                e.release(id);
            }
            prop_assert!(
                e.pool().pages_allocated() == 0,
                "retirement must return every page to the pool ({} still allocated)",
                e.pool().pages_allocated()
            );
            prop_assert_eq!(got[1].len(), 1 + retire_at);
            for (slot, stream) in got.iter().enumerate() {
                prop_assert!(
                    want[slot][..stream.len()] == stream[..],
                    "slot {slot} diverged on the {}-float page pool",
                    e.pool().page_floats()
                );
            }
            prop_assert!(e.pool().pages_peak() > 0, "the batch must have touched the pool");
        }
        Ok(())
    });
}

/// Typed admission under budget pressure: a request whose worst case
/// can never fit `max_batch_total_tokens`, `max_batch_prefill_tokens`,
/// or the page pool is rejected `Overloaded` at enqueue — not a panic,
/// not a silent queue stall — and the rejection is counted.
#[test]
fn worst_case_over_budget_is_rejected_with_typed_overloaded() {
    let mut rng = Rng::seed_from_u64(61);
    let s = generate(Task::PRe, &mut rng, 96);

    // total-token budget: prompt + max_new can never fit 64 tokens
    let (coord, engine) = start_coordinator(ServingConfig {
        max_batch_total_tokens: 64,
        ..Default::default()
    });
    let err = coord
        .open(Request { prompt: s.prompt.clone(), max_new: 32, ..Default::default() })
        .err()
        .expect("over-budget request must be rejected at enqueue");
    assert!(matches!(err, RequestError::Overloaded { .. }), "{err:?}");
    assert_eq!(err.kind(), "overloaded");
    assert_eq!(err.overload_detail(), Some("total_tokens"), "{err:?}");

    // prefill-token budget: the prompt alone exceeds the round budget
    let (coord2, engine2) = start_coordinator(ServingConfig {
        max_batch_prefill_tokens: 32,
        ..Default::default()
    });
    let err2 = coord2
        .open(Request { prompt: s.prompt.clone(), ..Default::default() })
        .err()
        .expect("prompt over the prefill budget must be rejected");
    assert!(matches!(err2, RequestError::Overloaded { .. }), "{err2:?}");
    assert_eq!(err2.overload_detail(), Some("prefill_tokens"), "{err2:?}");

    // page-pool budget: a 16-page pool can never hold the request's
    // worst case (per-layer prefill bucket + SA ring)
    let engine3 = EngineHandle::spawn_with_pool(artifacts(), 32, 512).unwrap();
    let coord3 = Coordinator::start(engine3.clone(), ServingConfig::default()).unwrap();
    let err3 = coord3
        .open(Request { prompt: s.prompt, ..Default::default() })
        .err()
        .expect("request over the page budget must be rejected");
    assert!(matches!(err3, RequestError::Overloaded { .. }), "{err3:?}");
    assert_eq!(err3.overload_detail(), Some("pages"), "{err3:?}");
    assert!(err3.to_string().contains("page"), "{err3}");
    let m = coord3.metrics.lock().unwrap();
    assert_eq!(m.requests_overloaded, 1);
    assert_eq!(m.requests_rejected, 1);
    assert!(m.summary().contains("overloaded=1"), "{}", m.summary());
    drop(m);
    common::assert_pool_drained(&engine);
    common::assert_pool_drained(&engine2);
    common::assert_pool_drained(&engine3);
}

/// A request that fits the budgets alone but not alongside the running
/// batch parks at the head of the queue and admits once budget drains —
/// the pair never shares a decode round, both complete, and pool
/// occupancy is visible in the metrics summary.
#[test]
fn over_budget_request_parks_then_completes() {
    // worst case per request: 96 prompt + 8 decode = 104 tokens; the
    // 160-token budget fits exactly one at a time
    let (coord, engine) = start_coordinator(ServingConfig {
        max_batch_total_tokens: 160,
        ..Default::default()
    });
    let prompt: Vec<u32> = (0..96).map(|i| (i as u32) % 250 + 1).collect();
    let req = || Request {
        prompt: prompt.clone(),
        max_new: 8,
        ignore_eos: true,
        ..Default::default()
    };
    let ha = coord.open(req()).unwrap();
    let hb = coord.open(req()).unwrap();
    let ra = ha.wait().unwrap();
    let rb = hb.wait().unwrap();
    assert_eq!(ra.tokens.len(), 8);
    assert_eq!(rb.tokens.len(), 8, "the parked request must complete after budget drains");
    // greedy determinism: identical prompts decode identical streams
    assert_eq!(ra.tokens, rb.tokens);

    let m = coord.metrics.lock().unwrap();
    assert_eq!(m.requests_completed, 2);
    assert_eq!(m.requests_rejected, 0, "parking must not reject");
    assert_eq!(
        m.decode_batch_size.percentile_us(100.0),
        1,
        "the pair must never share a round under the token budget"
    );
    assert!(m.pages_peak > 0, "decode rounds must report pool occupancy");
    let s = m.summary();
    assert!(s.contains("pages="), "{s}");
    assert!(s.contains("pages_peak="), "{s}");
    drop(m);
    common::assert_pool_drained(&engine);
}

/// Lifecycle satellite: `max_new == 0` is rejected with a typed
/// `Invalid` error at enqueue. The old path silently clamped it to one
/// generated token — a zero-budget request must never reach the engine.
#[test]
fn zero_max_new_is_rejected_invalid_at_enqueue() {
    let (coord, engine) = start_coordinator(ServingConfig::default());
    let prompt: Vec<u32> = (1..64).collect();
    let err = coord
        .open(Request { prompt, max_new: 0, ..Default::default() })
        .err()
        .expect("max_new == 0 must be rejected at enqueue");
    assert!(matches!(err, RequestError::Invalid(_)), "{err:?}");
    assert!(err.to_string().contains("max_new"), "{err}");
    let m = coord.metrics.lock().unwrap();
    assert_eq!(m.requests_rejected, 1);
    assert_eq!(m.requests_completed, 0);
    assert_eq!(m.tokens_generated, 0, "a zero-budget request must never reach the engine");
    drop(m);
    common::assert_pool_drained(&engine);
}

/// Lifecycle satellite: a session cancelled while its prefill is in
/// flight terminates with `Cancelled` and emits NO `Prefilled` (and no
/// tokens) — `finish_prefill` re-checks the cancel signal before
/// emitting. The old path announced `Prefilled` and only evicted the
/// request at the next decode sweep.
#[test]
fn cancel_during_prefill_emits_no_prefilled() {
    let (coord, engine) = start_coordinator(ServingConfig::default());
    // the largest prefill bucket: the cancel always lands before the
    // prefill completes
    let prompt: Vec<u32> = (0..2048).map(|i| (i as u32) % 250 + 1).collect();
    let h = coord
        .open(Request { prompt, max_new: 64, ignore_eos: true, ..Default::default() })
        .unwrap();
    h.cancel();
    let mut saw_output = false;
    let mut terminal = None;
    while let Some(ev) = h.recv_timeout(TIMEOUT) {
        match ev {
            SessionEvent::Prefilled { .. } | SessionEvent::Token { .. } => saw_output = true,
            SessionEvent::Error { error } => {
                terminal = Some(error);
                break;
            }
            SessionEvent::Done { .. } => panic!("cancelled request must not complete"),
            SessionEvent::Queued
            | SessionEvent::Preempted { .. }
            | SessionEvent::Resumed { .. } => {}
        }
    }
    assert_eq!(terminal, Some(RequestError::Cancelled));
    assert!(!saw_output, "no Prefilled/Token may be emitted after cancellation");
    let m = coord.metrics.lock().unwrap();
    assert_eq!(m.requests_cancelled, 1);
    drop(m);
    common::assert_pool_drained(&engine);
}

/// Deadline variant of the same fix: a deadline that elapses during the
/// prefill terminates the session with `DeadlineExceeded` before any
/// `Prefilled` is announced.
#[test]
fn deadline_elapsing_during_prefill_emits_no_prefilled() {
    let (coord, engine) = start_coordinator(ServingConfig::default());
    let prompt: Vec<u32> = (0..2048).map(|i| (i as u32) % 250 + 1).collect();
    let h = coord
        .open(Request {
            prompt,
            max_new: 64,
            ignore_eos: true,
            deadline_ms: Some(1),
            ..Default::default()
        })
        .unwrap();
    let mut saw_output = false;
    let mut terminal = None;
    while let Some(ev) = h.recv_timeout(TIMEOUT) {
        match ev {
            SessionEvent::Prefilled { .. } | SessionEvent::Token { .. } => saw_output = true,
            SessionEvent::Error { error } => {
                terminal = Some(error);
                break;
            }
            SessionEvent::Done { .. } => panic!("expired request must not complete"),
            SessionEvent::Queued
            | SessionEvent::Preempted { .. }
            | SessionEvent::Resumed { .. } => {}
        }
    }
    assert_eq!(terminal, Some(RequestError::DeadlineExceeded));
    assert!(!saw_output, "no Prefilled/Token may be emitted after the deadline elapsed");
    let m = coord.metrics.lock().unwrap();
    assert_eq!(m.requests_expired, 1);
    drop(m);
    common::assert_pool_drained(&engine);
}
