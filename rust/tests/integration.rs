//! Integration tests over the real AOT artifacts: engine load, routed
//! generation, dense/sparse decode consistency, coordinator round-trip.
//!
//! These tests need `make artifacts`; they skip (pass trivially, with a
//! stderr note) when the artifacts are absent so `cargo test` stays
//! green on a fresh checkout.

use std::path::PathBuf;

use flux_attention::config::ServingConfig;
use flux_attention::coordinator::{Coordinator, Request};
use flux_attention::engine::{Engine, EngineHandle};
use flux_attention::router::{AttnMode, DecodeMode, Policy};
use flux_attention::workload::{generate, Task};
use flux_attention::util::rng::Rng;

fn artifacts() -> Option<PathBuf> {
    let p = PathBuf::from(std::env::var("FLUX_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()));
    if p.join("manifest.json").exists() && p.join("weights.bin").exists() {
        Some(p)
    } else {
        eprintln!("integration tests skipped: run `make artifacts` first");
        None
    }
}

#[test]
fn engine_loads_and_reports_config() {
    let Some(dir) = artifacts() else { return };
    let engine = Engine::load(&dir).unwrap();
    assert_eq!(engine.cfg().model.n_heads * engine.cfg().model.head_dim,
               engine.cfg().model.d_model);
    assert!(engine.routers.contains_key("balanced"), "balanced router missing");
}

#[test]
fn generation_is_deterministic() {
    let Some(dir) = artifacts() else { return };
    let mut engine = Engine::load(&dir).unwrap();
    let mut rng = Rng::seed_from_u64(0);
    let s = generate(Task::PRe, &mut rng, 256);
    let policy = Policy::Flux { sa_mode: AttnMode::Ssa, decode: DecodeMode::Dense };
    let (g1, r1) = engine.generate(&s.prompt, &policy, "balanced", 4).unwrap();
    let (g2, r2) = engine.generate(&s.prompt, &policy, "balanced", 4).unwrap();
    assert_eq!(g1, g2, "greedy generation must be deterministic");
    assert_eq!(r1.modes, r2.modes, "routing must be deterministic");
}

#[test]
fn dense_decode_matches_full_prefill_teacher_forcing() {
    // prefill(prompt) + decode(token) must equal prefill(prompt+token)
    // for the backbone policy — the core serving-correctness invariant.
    let Some(dir) = artifacts() else { return };
    let mut engine = Engine::load(&dir).unwrap();
    let mut rng = Rng::seed_from_u64(3);
    let s = generate(Task::Qasper, &mut rng, 200);
    let prompt = &s.prompt;

    // path A: prefill prompt, decode one step
    let (id, report) = engine.prefill(prompt, &Policy::Backbone, "balanced").unwrap();
    let tok_a = engine.decode_step(id).unwrap();
    engine.release(id);

    // path B: prefill prompt + first generated token; its lm_head argmax
    // must equal tok_a
    let mut extended = prompt.clone();
    extended.push(report.first_token);
    let (id2, report2) = engine.prefill(&extended, &Policy::Backbone, "balanced").unwrap();
    engine.release(id2);
    assert_eq!(
        tok_a, report2.first_token,
        "decode step diverged from prefill teacher-forcing"
    );
}

#[test]
fn sparse_decode_caches_are_bounded() {
    let Some(dir) = artifacts() else { return };
    let mut engine = Engine::load(&dir).unwrap();
    let n_layers = engine.cfg().model.n_layers;
    let sa_bytes = 2 * engine.cfg().sa_buf * engine.cfg().model.d_model * 4;
    let mut rng = Rng::seed_from_u64(4);
    let s = generate(Task::Gov, &mut rng, 1024);
    let policy = Policy::Static {
        modes: vec![AttnMode::Ssa; n_layers],
        decode: DecodeMode::Sparse,
    };
    let (id, report) = engine.prefill(&s.prompt, &policy, "balanced").unwrap();
    // all-sparse request: KV must be tiny vs the dense equivalent
    assert!(
        report.kv_bytes <= n_layers * sa_bytes,
        "sparse KV {} exceeds bound {}",
        report.kv_bytes,
        n_layers * sa_bytes
    );
    for _ in 0..4 {
        engine.decode_step(id).unwrap();
    }
    let state = engine.request_state(id).unwrap();
    let after: usize = state.caches.iter().map(|c| c.bytes()).sum();
    assert_eq!(after, report.kv_bytes, "sparse decode must not grow KV");
    engine.release(id);
}

#[test]
fn flux_routing_reacts_to_task_category() {
    let Some(dir) = artifacts() else { return };
    let mut engine = Engine::load(&dir).unwrap();
    let policy = Policy::Flux { sa_mode: AttnMode::Ssa, decode: DecodeMode::Dense };
    let mut rng = Rng::seed_from_u64(5);
    let mut omsr = std::collections::HashMap::new();
    for task in [Task::PRe, Task::Gov] {
        let mut sum = 0.0;
        for _ in 0..4 {
            let s = generate(task, &mut rng, 512);
            let (id, r) = engine.prefill(&s.prompt, &policy, "balanced").unwrap();
            engine.release(id);
            sum += r.omsr;
        }
        omsr.insert(task.name(), sum / 4.0);
    }
    // both must be valid ratios; the trained router is expected to
    // sparsify holistic tasks at least as much as retrieval tasks
    for (_, &v) in &omsr {
        assert!((0.0..=1.0).contains(&v));
    }
    assert!(
        omsr["gov"] >= omsr["pre"] - 1e-9,
        "holistic should be at least as sparse: {omsr:?}"
    );
}

#[test]
fn coordinator_serves_concurrent_requests() {
    let Some(dir) = artifacts() else { return };
    let engine = EngineHandle::spawn(dir).unwrap();
    let coord = Coordinator::start(engine, ServingConfig::default());
    let mut rng = Rng::seed_from_u64(6);
    let mut handles = vec![];
    for task in [Task::PRe, Task::Gov, Task::Trec, Task::HotQA] {
        let s = generate(task, &mut rng, 256);
        let coord = coord.clone();
        handles.push(std::thread::spawn(move || {
            coord.submit(Request {
                max_new: 3,
                prompt: s.prompt,
                policy: Policy::Flux { sa_mode: AttnMode::Ssa, decode: DecodeMode::Dense },
                router: "balanced".into(),
            })
        }));
    }
    for h in handles {
        let resp = h.join().unwrap().unwrap();
        assert!(!resp.tokens.is_empty());
        assert!(resp.e2e_us >= resp.ttft_us);
    }
    let m = coord.metrics.lock().unwrap();
    assert_eq!(m.requests_completed, 4);
    assert!(m.tokens_generated >= 4);
}

#[test]
fn static_policies_execute_all_modes() {
    let Some(dir) = artifacts() else { return };
    let mut engine = Engine::load(&dir).unwrap();
    let n_layers = engine.cfg().model.n_layers;
    let mut rng = Rng::seed_from_u64(7);
    let s = generate(Task::PRe, &mut rng, 128);
    for mode in [AttnMode::Fa, AttnMode::Ssa, AttnMode::Ta, AttnMode::Xa] {
        let policy = Policy::Static { modes: vec![mode; n_layers], decode: DecodeMode::Dense };
        let (gen, report) = engine.generate(&s.prompt, &policy, "balanced", 2).unwrap();
        assert_eq!(gen.len(), 2.min(gen.len()).max(1));
        let expected = if mode == AttnMode::Fa { 0.0 } else { 1.0 };
        assert!((report.omsr - expected).abs() < 1e-9);
    }
}

#[test]
fn entropy_profile_is_finite_and_per_layer() {
    let Some(dir) = artifacts() else { return };
    let mut engine = Engine::load(&dir).unwrap();
    let mut rng = Rng::seed_from_u64(8);
    let s = generate(Task::PRe, &mut rng, 256);
    let scores = engine.profile_entropy(&s.prompt, 64).unwrap();
    assert_eq!(scores.len(), engine.cfg().model.n_layers);
    for &sc in &scores {
        assert!(sc.is_finite() && sc >= 0.0);
    }
}
