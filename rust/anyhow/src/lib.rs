//! In-tree substitute for the `anyhow` crate (offline build environment:
//! no registry access — DESIGN.md §4). Implements the subset of the real
//! API this workspace uses: [`Error`], [`Result`], the [`Context`]
//! extension trait for `Result` and `Option`, [`Error::new`] +
//! [`Error::downcast_ref`]/[`Error::downcast`] for typed recovery
//! (DESIGN.md §12 routes `EngineFailed` into supervision this way), and
//! the `anyhow!`, `bail!` and `ensure!` macros. Swapping in the
//! registry crate requires only a Cargo.toml change — call sites are
//! source-compatible.

use std::any::Any;
use std::fmt;

/// A message-backed error value that, when built from a concrete error
/// type ([`Error::new`], the blanket `From`, or `?`), also carries that
/// value for [`Error::downcast_ref`]. Like the real `anyhow::Error` it
/// deliberately does NOT implement `std::error::Error`, which is what
/// allows the blanket `From<E: std::error::Error>` conversion below
/// (and therefore `?` on `io::Error`, `RecvError`, `ParseIntError`, …).
pub struct Error {
    msg: String,
    /// The concrete error this was built from, kept for downcasting.
    /// `None` for message-only errors (`anyhow!`, `Error::msg`).
    source: Option<Box<dyn Any + Send + Sync>>,
}

impl Error {
    /// Construct from anything displayable.
    pub fn msg<M: fmt::Display>(m: M) -> Self {
        Error { msg: m.to_string(), source: None }
    }

    /// Construct from a concrete error value, keeping it for
    /// [`Error::downcast_ref`] — the typed-recovery seam.
    pub fn new<E: std::error::Error + Send + Sync + 'static>(e: E) -> Self {
        Error { msg: e.to_string(), source: Some(Box::new(e)) }
    }

    /// A reference to the concrete error this was built from, if it is
    /// a `T`. Context wrapping prefixes the message but keeps the
    /// downcast target (matching the real anyhow's chain walk).
    pub fn downcast_ref<T: Any>(&self) -> Option<&T> {
        self.source.as_ref()?.downcast_ref::<T>()
    }

    /// Consume into the concrete error this was built from, or give
    /// `self` back unchanged if it is not a `T`.
    pub fn downcast<T: Any>(self) -> std::result::Result<T, Self> {
        let Error { msg, source } = self;
        match source {
            Some(b) => match b.downcast::<T>() {
                Ok(t) => Ok(*t),
                Err(b) => Err(Error { msg, source: Some(b) }),
            },
            None => Err(Error { msg, source: None }),
        }
    }

    fn wrap<C: fmt::Display>(self, ctx: C) -> Self {
        Error { msg: format!("{ctx}: {}", self.msg), source: self.source }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error::new(e)
    }
}

/// `anyhow::Result<T>` — defaults the error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Context-attachment extension trait for `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| e.into().wrap(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// `anyhow!("format {args}")` / `anyhow!(displayable)` — build an [`Error`].
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
}

/// `bail!(...)` — early-return an `Err(anyhow!(...))`.
#[macro_export]
macro_rules! bail {
    ($($tt:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($tt)*))
    };
}

/// `ensure!(cond, ...)` — bail unless `cond` holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", ::std::stringify!($cond));
        }
    };
    ($cond:expr, $($tt:tt)*) => {
        if !($cond) {
            $crate::bail!($($tt)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::Other, "disk on fire")
    }

    #[test]
    fn question_mark_on_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(inner().unwrap_err().to_string(), "disk on fire");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading weights").unwrap_err();
        assert_eq!(e.to_string(), "reading weights: disk on fire");

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing field '{}'", "x")).unwrap_err();
        assert_eq!(e.to_string(), "missing field 'x'");

        let some: Option<u32> = Some(7);
        assert_eq!(some.context("unused").unwrap(), 7);
    }

    #[test]
    fn macros_format_and_bail() {
        fn inner(n: usize) -> Result<usize> {
            ensure!(n < 10, "n too large: {n}");
            if n == 3 {
                bail!("three is right out");
            }
            Ok(n)
        }
        assert_eq!(inner(2).unwrap(), 2);
        assert_eq!(inner(3).unwrap_err().to_string(), "three is right out");
        assert_eq!(inner(11).unwrap_err().to_string(), "n too large: 11");
        let e = anyhow!("exe {} not loaded", "lm_head");
        assert_eq!(e.to_string(), "exe lm_head not loaded");
    }

    #[test]
    fn error_is_send_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<Error>();
    }

    /// Typed recovery: `?`/`Error::new` keep the concrete error for
    /// `downcast_ref`, context wrapping preserves it, and message-only
    /// errors (`anyhow!`) downcast to nothing.
    #[test]
    fn downcast_recovers_the_concrete_error() {
        #[derive(Debug, PartialEq)]
        struct Marker(u32);
        impl fmt::Display for Marker {
            fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
                write!(f, "marker {}", self.0)
            }
        }
        impl std::error::Error for Marker {}

        let e = Error::new(Marker(7));
        assert_eq!(e.downcast_ref::<Marker>(), Some(&Marker(7)));
        assert!(e.downcast_ref::<std::io::Error>().is_none());

        let wrapped = Err::<(), _>(Marker(7)).context("during prefill").unwrap_err();
        assert_eq!(wrapped.to_string(), "during prefill: marker 7");
        assert_eq!(wrapped.downcast_ref::<Marker>(), Some(&Marker(7)));
        assert_eq!(wrapped.downcast::<Marker>().unwrap(), Marker(7));

        let plain = anyhow!("no source");
        assert!(plain.downcast_ref::<Marker>().is_none());
        let back = plain.downcast::<Marker>().unwrap_err();
        assert_eq!(back.to_string(), "no source");
    }
}
